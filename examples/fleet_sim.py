"""Heterogeneous fleet simulation: Ampere vs. SplitFed under churn.

A 200-device population (five device classes: three Jetson tiers + two
phone tiers) with exponential online/offline churn, mid-round dropout
hazard, straggler deadlines, heartbeat liveness and elastic cohort sizing
(16-cohort target) trains on Dirichlet non-IID data.  ONE event-driven
fleet trace — who is online, who gets picked, who drops — drives both:

* Ampere (``AmpereTrainer.run_fleet``): vmapped pool-fed device rounds,
  one-shot activation consolidation, centralized server phase;
* SplitFed (``SFLTrainer.run_rounds(cohort_plan=...)``): the same cohorts
  replayed, with per-round wall-clock re-priced for SplitFed's
  per-iteration activation/gradient exchange on the same device profiles.

Prints per-round wall-clock/accuracy traces for both systems and writes
``results/fleet_sim.json``.  Runs on CPU in a few minutes.

    PYTHONPATH=src python examples/fleet_sim.py
"""

import json
import os
import time

from repro.configs import registry
from repro.configs.base import FedConfig, OptimConfig, RunConfig
from repro.core.baselines import SFLTrainer
from repro.core.uit import AmpereTrainer
from repro.data import federate, make_dataset_for_model
from repro.fleet import (FleetConfig, FleetScheduler, make_latency_fn,
                         sample_population, trace_round_times)
from repro.models import build_model

ARCH = "mobilenet-l"
N_DEVICES = 200
ROUNDS = 20
SERVER_EPOCHS = 4

t0 = time.time()
cfg = registry.get_smoke_config(ARCH)
model = build_model(cfg)
run_cfg = RunConfig(
    arch=ARCH,
    fed=FedConfig(num_clients=N_DEVICES, clients_per_round=16,
                  local_steps=2, device_batch_size=8, server_batch_size=64,
                  dirichlet_alpha=0.33),
    optim=OptimConfig(name="momentum", lr=0.2, schedule="inverse_time",
                      decay_gamma=0.005),
)

train = make_dataset_for_model(model, 3200, seed=0)
test = make_dataset_for_model(model, 256, seed=1)
clients = federate(train, N_DEVICES, run_cfg.fed.dirichlet_alpha, seed=0)

# ---------------------------------------------------------------- fleet trace
fleet_cfg = FleetConfig(
    n_devices=N_DEVICES, seed=0,
    mean_session_rounds=8.0, mean_off_rounds=3.0, p_online0=0.7,
    dropout_hazard=0.04, deadline_factor=2.5,
    min_cohort=8, max_cohort=16, init_cohort=16,
    target_round_time_factor=1.5)
population = sample_population(fleet_cfg)
lat_ampere = make_latency_fn(model, run_cfg, algo="ampere")
scheduler = FleetScheduler(population, lat_ampere, fleet_cfg)
trace = scheduler.simulate(ROUNDS)
n_assign = sum(1 for e in trace.events if e[1] == "assign")
n_drop = sum(1 for e in trace.events if e[1] == "dropout")
print(f"fleet trace: {len(trace.events)} events, {ROUNDS} rounds, "
      f"{n_assign} assignments, {n_drop} mid-round dropouts, "
      f"cohorts={trace.cohort_sizes}")

# ------------------------------------------------------------------- Ampere
print("\n== Ampere under the fleet trace ==")
ampere = AmpereTrainer(model, run_cfg, clients, test, log_echo=True)
out = ampere.run_fleet(trace, max_server_epochs=SERVER_EPOCHS)
acc_a = out["history"]["server"][-1]["val_acc"]
time_a = out["history"]["sim_time"]
comm_a = out["history"]["comm_bytes"] / 1e6

# -------------------------------------------- SplitFed on the same trace
# identical cohorts/dropouts; wall-clock re-priced for SplitFed's
# per-iteration activation+gradient exchange on the same device profiles
print("\n== SplitFed replaying the identical trace ==")
lat_sfl = make_latency_fn(model, run_cfg, algo="splitfed")
sfl_times = trace_round_times(trace, population, lat_sfl)
plan = [dict(p.as_cohort(), round_time=t)
        for p, t in zip(trace.rounds, sfl_times)]
sfl = SFLTrainer(model, run_cfg, clients, test, variant="splitfed",
                 log_echo=True)
res = sfl.run_rounds(ROUNDS, cohort_plan=plan)
acc_s = res["history"]["rounds"][-1]["val_acc"]
time_s = res["history"]["sim_time"]
comm_s = res["history"]["comm_bytes"] / 1e6

# ------------------------------------------------------------------ report
print("\nround |  K | surv | drop |   t_ampere |     t_sfl | acc_ampere | acc_sfl")
tA = tS = 0.0
rows = []
for p, ts in zip(trace.rounds, sfl_times):
    r = p.round_idx
    tA = p.t_end
    tS += ts
    da = out["history"]["device"][r] if r < len(out["history"]["device"]) \
        else {}
    ds = res["history"]["rounds"][r] if r < len(res["history"]["rounds"]) \
        else {}
    rows.append({"round": r, "cohort": p.cohort_size,
                 "survivors": len(p.clients), "dropped": len(p.dropped),
                 "t_ampere_s": tA, "t_sfl_s": tS,
                 "acc_ampere_aux": da.get("val_acc"),
                 "acc_sfl": ds.get("val_acc")})
    fa = (f"{da['val_acc']:10.3f}" if "val_acc" in da
          else "         -")  # device phase early-stopped on aux val
    fs = f"{ds['val_acc']:7.3f}" if "val_acc" in ds else "      -"
    print(f"{r:5d} | {p.cohort_size:2d} | {len(p.clients):4d} "
          f"| {len(p.dropped):4d} | {tA:10.3f} | {tS:9.3f} | {fa} | {fs}")

print(f"\nAmpere:   acc={acc_a:.3f}  sim_time={time_a:.1f}s  comm={comm_a:.1f} MB")
print(f"SplitFed: acc={acc_s:.3f}  sim_time={time_s:.1f}s  comm={comm_s:.1f} MB")
if time_s > 0:
    print(f"training-time reduction: {100 * (1 - time_a / time_s):.1f}%  "
          f"comm reduction: {100 * (1 - comm_a / comm_s):.1f}%")
print(f"wall clock: {time.time() - t0:.0f}s")

os.makedirs("results", exist_ok=True)
with open("results/fleet_sim.json", "w") as f:
    json.dump({"config": {"arch": ARCH, "n_devices": N_DEVICES,
                          "rounds": ROUNDS,
                          "cohort_sizes": trace.cohort_sizes},
               "per_round": rows,
               "ampere": {"acc": acc_a, "sim_time_s": time_a,
                          "comm_mb": comm_a},
               "splitfed": {"acc": acc_s, "sim_time_s": time_s,
                            "comm_mb": comm_s}}, f, indent=1)
print("wrote results/fleet_sim.json")
