"""Serving example: batched prefill + greedy decode with KV caches over a
(small) LM — the same prefill/decode graphs the multi-pod dry-run lowers
for the decode_32k / long_500k cells.

    PYTHONPATH=src python examples/serve_lm.py --arch mamba2-370m
"""

import argparse
import time

import jax
import numpy as np

from repro.configs import registry
from repro.launch.serve import LMServer
from repro.models import build_model


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-1.7b",
                    choices=list(registry.ASSIGNED_ARCHS))
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=24)
    ap.add_argument("--new-tokens", type=int, default=24)
    args = ap.parse_args()

    cfg = registry.get_smoke_config(args.arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    server = LMServer(model, params,
                      max_len=args.prompt_len + args.new_tokens + 1)

    prompts = np.random.default_rng(0).integers(
        0, cfg.vocab_size, (args.batch, args.prompt_len), dtype=np.int32)
    t0 = time.time()
    out = server.generate(prompts, args.new_tokens)
    dt = time.time() - t0
    print(f"arch={args.arch} generated {out.shape} in {dt:.2f}s "
          f"({args.batch * args.new_tokens / dt:.1f} tok/s incl. compile)")
    print("first sequence:", out[0].tolist())


if __name__ == "__main__":
    main()
