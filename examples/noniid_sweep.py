"""Data-heterogeneity sweep (paper Fig. 10 in miniature).

Runs Ampere and SplitFed across three non-IID degrees (alpha = 1.0 IID,
0.33 moderate, 0.1 severe) and reports the accuracy spread — Ampere's
activation consolidation keeps the server block training on a near-IID
mixture regardless of alpha.

    PYTHONPATH=src python examples/noniid_sweep.py
"""

import numpy as np

from repro.configs import registry
from repro.configs.base import FedConfig, OptimConfig, RunConfig
from repro.core.baselines import SFLTrainer
from repro.core.uit import AmpereTrainer
from repro.data import class_histogram, federate, heterogeneity_index, \
    make_dataset_for_model
from repro.models import build_model

ARCH = "mobilenet-l"
ROUNDS, SERVER_EPOCHS = 10, 6

cfg = registry.get_smoke_config(ARCH)
model = build_model(cfg)
test = make_dataset_for_model(model, 384, seed=1)

results = {}
for alpha in (1.0, 0.33, 0.1):
    run_cfg = RunConfig(
        arch=ARCH,
        fed=FedConfig(num_clients=8, clients_per_round=4, local_steps=8,
                      device_batch_size=16, server_batch_size=32,
                      dirichlet_alpha=alpha),
        optim=OptimConfig(name="momentum", lr=0.2, schedule="inverse_time",
                          decay_gamma=0.005))
    train = make_dataset_for_model(model, 1536, seed=0)
    clients = federate(train, 8, alpha, seed=0)

    amp = AmpereTrainer(model, run_cfg, clients, test)
    a = amp.run_all(max_device_rounds=ROUNDS, max_server_epochs=SERVER_EPOCHS)
    sfl = SFLTrainer(model, run_cfg, clients, test, variant="splitfed")
    s = sfl.run_rounds(ROUNDS)
    results[alpha] = {
        "ampere": a["history"]["server"][-1]["val_acc"],
        "splitfed": s["history"]["rounds"][-1]["val_acc"],
    }
    print(f"alpha={alpha}: ampere={results[alpha]['ampere']:.3f} "
          f"splitfed={results[alpha]['splitfed']:.3f}")

amp_accs = [r["ampere"] for r in results.values()]
sfl_accs = [r["splitfed"] for r in results.values()]
print(f"\naccuracy std across alphas: "
      f"ampere={np.std(amp_accs):.4f}  splitfed={np.std(sfl_accs):.4f}")
