"""End-to-end driver: split-federated LM pre-training with Ampere.

Trains a decoder LM (default ~8M params for CPU; --big builds a ~100M
model) for a few hundred steps on a synthetic domain-mixture corpus,
Dirichlet-partitioned across federated clients:

  phase 1 — clients train (embedding + first layer + auxiliary head) with
            local losses, FedAvg-aggregated each round;
  phase 2 — one-shot activation upload into the consolidation store;
  phase 3 — the server trains the remaining layers on consolidated
            activations (the roofline-bearing DPxTP step on a pod).

    PYTHONPATH=src python examples/train_ampere_lm.py
    PYTHONPATH=src python examples/train_ampere_lm.py --big --rounds 30
"""

import argparse
import dataclasses

from repro.configs.base import (FedConfig, LMConfig, OptimConfig, RunConfig,
                                SplitConfig)
from repro.core.uit import AmpereTrainer
from repro.data import federate, make_dataset_for_model
from repro.models import build_model


def small_lm(big: bool) -> LMConfig:
    if big:  # ~100M params
        return LMConfig(name="ampere-lm-100m", family="dense", num_layers=8,
                        d_model=512, num_heads=8, num_kv_heads=4,
                        head_dim=64, d_ff=2048, vocab_size=8192,
                        qk_norm=True, tie_embeddings=True, dtype="float32")
    return LMConfig(name="ampere-lm-8m", family="dense", num_layers=4,
                    d_model=128, num_heads=4, num_kv_heads=2, head_dim=32,
                    d_ff=512, vocab_size=1024, qk_norm=True,
                    tie_embeddings=True, dtype="float32")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--big", action="store_true")
    ap.add_argument("--rounds", type=int, default=15)
    ap.add_argument("--server-epochs", type=int, default=5)
    ap.add_argument("--samples", type=int, default=768)
    ap.add_argument("--seq-len", type=int, default=64)
    args = ap.parse_args()

    cfg = small_lm(args.big)
    model = build_model(cfg)
    print(f"model: {cfg.name} ({cfg.param_count()/1e6:.1f}M params)")

    run_cfg = RunConfig(
        arch=cfg.name,
        split=SplitConfig(split_point=1, aux_ratio=0.5),
        fed=FedConfig(num_clients=8, clients_per_round=4, local_steps=8,
                      device_batch_size=8, server_batch_size=16,
                      dirichlet_alpha=0.33),
        optim=OptimConfig(name="adam", lr=2e-3, schedule="inverse_time",
                          decay_gamma=0.002),
    )
    train = make_dataset_for_model(model, args.samples,
                                   seq_len=args.seq_len, seed=0)
    test = make_dataset_for_model(model, args.samples // 4,
                                  seq_len=args.seq_len, seed=1)
    clients = federate(train, run_cfg.fed.num_clients,
                       run_cfg.fed.dirichlet_alpha, seed=0)

    tr = AmpereTrainer(model, run_cfg, clients, test, log_echo=True)
    out = tr.run_all(max_device_rounds=args.rounds,
                     max_server_epochs=args.server_epochs)
    h = out["history"]
    print(f"\ndevice-phase loss: {h['device'][0]['loss']:.3f} -> "
          f"{h['device'][-1]['loss']:.3f} over {len(h['device'])} rounds")
    print(f"server-phase val loss: {h['server'][0]['val_loss']:.3f} -> "
          f"{h['server'][-1]['val_loss']:.3f} over {len(h['server'])} epochs")
    print(f"total device-server communication: {h['comm_bytes']/1e6:.1f} MB")


if __name__ == "__main__":
    main()
