"""Quickstart: declarative Ampere vs. SplitFed in ~30 lines.

One :class:`~repro.experiments.ExperimentSpec` drives both systems on
the paper's MobileNet-L-style CNN (reduced config) over the same
synthetic non-IID partition: Ampere's three-phase pipeline (federated
device phase, one-shot activation consolidation, centralized server
phase) and the SplitFed baseline, through one
:func:`~repro.experiments.run_experiment` call.

    PYTHONPATH=src python examples/quickstart.py
"""

from repro.configs.base import FedConfig, OptimConfig, RunConfig
from repro.experiments import DataSpec, ExperimentSpec, run_experiment

spec = ExperimentSpec(
    name="quickstart",
    systems=("ampere", "splitfed"),
    arch="mobilenet-l",
    run=RunConfig(
        arch="mobilenet-l",
        fed=FedConfig(num_clients=8, clients_per_round=4, local_steps=8,
                      device_batch_size=16, server_batch_size=32,
                      dirichlet_alpha=0.33),
        optim=OptimConfig(name="momentum", lr=0.2, schedule="inverse_time",
                          decay_gamma=0.005),
    ),
    data=DataSpec(train_samples=1536, eval_samples=384),
    max_rounds=10, max_server_epochs=8,
)

out = run_experiment(spec, log_echo=True)

acc_a = out["results"]["ampere"]["history"]["server"][-1]["val_acc"]
comm_a = out["summary"]["ampere"]["comm_bytes"] / 1e6
acc_s = out["results"]["splitfed"]["history"]["rounds"][-1]["val_acc"]
comm_s = out["summary"]["splitfed"]["comm_bytes"] / 1e6

print(f"\nAmpere:   acc={acc_a:.3f}  comm={comm_a:.1f} MB")
print(f"SplitFed: acc={acc_s:.3f}  comm={comm_s:.1f} MB")
print(f"comm reduction: {100 * (1 - comm_a / comm_s):.1f}%")
print(f"wrote {out['results_dir']}/summary.json")
