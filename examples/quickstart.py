"""Quickstart: Ampere split-federated training in ~40 lines.

Trains the paper's MobileNet-L-style CNN (reduced config) on synthetic
non-IID CIFAR-like data with the full three-phase Ampere pipeline —
federated device phase, one-shot activation consolidation, centralized
server phase — and compares communication against SplitFed.

    PYTHONPATH=src python examples/quickstart.py
"""

from repro.configs import registry
from repro.configs.base import FedConfig, OptimConfig, RunConfig
from repro.core.uit import AmpereTrainer
from repro.core.baselines import SFLTrainer
from repro.data import federate, make_dataset_for_model
from repro.models import build_model

ARCH = "mobilenet-l"

cfg = registry.get_smoke_config(ARCH)
model = build_model(cfg)
run_cfg = RunConfig(
    arch=ARCH,
    fed=FedConfig(num_clients=8, clients_per_round=4, local_steps=8,
                  device_batch_size=16, server_batch_size=32,
                  dirichlet_alpha=0.33),
    optim=OptimConfig(name="momentum", lr=0.2, schedule="inverse_time",
                      decay_gamma=0.005),
)

train = make_dataset_for_model(model, 1536, seed=0)
test = make_dataset_for_model(model, 384, seed=1)
clients = federate(train, run_cfg.fed.num_clients,
                   run_cfg.fed.dirichlet_alpha, seed=0)

print("== Ampere (UIT + auxiliary net + activation consolidation) ==")
ampere = AmpereTrainer(model, run_cfg, clients, test, log_echo=True)
out = ampere.run_all(max_device_rounds=10, max_server_epochs=8)
acc_a = out["history"]["server"][-1]["val_acc"]
comm_a = out["history"]["comm_bytes"] / 1e6

print("\n== SplitFed baseline (same budget of rounds) ==")
sfl = SFLTrainer(model, run_cfg, clients, test, variant="splitfed",
                 log_echo=True)
res = sfl.run_rounds(10)
acc_s = res["history"]["rounds"][-1]["val_acc"]
comm_s = res["history"]["comm_bytes"] / 1e6

print(f"\nAmpere:   acc={acc_a:.3f}  comm={comm_a:.1f} MB")
print(f"SplitFed: acc={acc_s:.3f}  comm={comm_s:.1f} MB")
print(f"comm reduction: {100 * (1 - comm_a / comm_s):.1f}%")
