#!/usr/bin/env bash
# Inner-loop CI: fast test tier, then the perf-regression gate.
#
#   scripts/ci.sh            # pytest -m "not slow" + bench gate
#   CI_SLOW=1 scripts/ci.sh  # also run the slow end-to-end tier
#
# The bench gate re-runs bench_step / bench_fleet / bench_attention and
# compares against the committed BENCH_step.json / BENCH_fleet.json /
# BENCH_attention.json (scripts/check_bench_regression.py; >25% step-time
# regression fails — CPU boxes are noisy, the precise trend lives in the
# committed snapshots).
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

python -m pytest -q -m "not slow"
if [[ "${CI_SLOW:-0}" == "1" ]]; then
    python -m pytest -q -m slow
fi
# static kernel-safety + determinism gate: Pallas alias/alignment/VMEM
# geometry over the registered config matrix (cached per source hash)
# plus the replay-determinism lint; fails only on findings not in the
# committed STATICCHECK_baseline.json (same contract as the bench gate).
python scripts/staticcheck.py --gate
# spec validation + system registry smoke over the committed comparison spec
python scripts/run_experiment.py examples/specs/compare_smoke.json --dry-run
# seeded chaos smoke: drops/corruption/duplicates/torn writes injected at
# the transport + storage boundaries; the run must complete (retries +
# quorum absorb the faults) on a tiny vit in well under 30s.  The spec
# enables observability, so the run emits a Perfetto trace.json + CRC'd
# spans.jsonl per system — validate them (schema + CRCs) and require
# nonzero retry spans, proving fault injection exercised the retry path.
CHAOS_DIR=$(mktemp -d)
python scripts/run_experiment.py examples/specs/chaos_smoke.json \
    --results-dir "$CHAOS_DIR"
python scripts/trace_report.py "$CHAOS_DIR" --validate --require-retries \
    --out "$CHAOS_DIR/report.md"
rm -rf "$CHAOS_DIR"
# streaming smoke: same chaos fault plan, but the activation upload goes
# through the memmap ring (CRC-committed segments, torn writes repaired,
# watermark backpressure) and server epochs overlap the device round —
# the summary's phase table must report nonzero overlapped seconds.
STREAM_DIR=$(mktemp -d)
python scripts/run_experiment.py examples/specs/streaming_smoke.json \
    --results-dir "$STREAM_DIR"
python - "$STREAM_DIR" <<'PY'
import json, sys
summary = json.load(open(f"{sys.argv[1]}/summary.json"))["summary"]["ampere"]
rows = {r["phase"]: r for r in summary["phases"]}
overlap = rows.get("server", {}).get("overlap_s", 0.0)
assert overlap > 0.0, f"streaming smoke: no server/device overlap: {rows}"
print(f"streaming smoke OK: overlap_s={overlap}")
PY
rm -rf "$STREAM_DIR"
# heterogeneous-cut smoke: per_profile CutPolicy over a two-class fleet
# (phone-3g pinned deeper via overrides — at smoke scale device compute
# is negligible, so the cost model alone resolves uniform).  The run
# must consolidate/train/aggregate across two cut depths end-to-end; the
# summary must record >= 2 distinct per-class cuts and a phase table
# whose analytic comm bytes balance (down == up per exchange phase,
# up-only for the one-shot activation transfer).
CUT_DIR=$(mktemp -d)
python scripts/run_experiment.py examples/specs/cut_smoke.json \
    --results-dir "$CUT_DIR"
python - "$CUT_DIR" <<'PY'
import json, sys
summary = json.load(open(f"{sys.argv[1]}/summary.json"))["summary"]["ampere"]
cuts = summary["cuts"]
assert not cuts["uniform"] and len(set(cuts["by_class"].values())) >= 2, \
    f"cut smoke: expected heterogeneous per-class cuts, got {cuts}"
rows = {r["phase"]: r for r in summary["phases"]}
for phase, r in rows.items():
    assert r["bytes_total"] == r["bytes_up"] + r["bytes_down"], \
        f"cut smoke: unbalanced bytes in phase {phase}: {r}"
assert rows["fleet"]["bytes_up"] == rows["fleet"]["bytes_down"] > 0, \
    f"cut smoke: fleet exchange not symmetric: {rows['fleet']}"
assert rows["transfer"]["bytes_up"] > 0 and \
    rows["transfer"]["bytes_down"] == 0, \
    f"cut smoke: one-shot upload should be up-only: {rows['transfer']}"
print(f"cut smoke OK: cuts={cuts['by_class']} depths={cuts['depths']}")
PY
rm -rf "$CUT_DIR"
python -m benchmarks.run --gate
