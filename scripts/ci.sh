#!/usr/bin/env bash
# Inner-loop CI: fast test tier, then the perf-regression gate.
#
#   scripts/ci.sh            # pytest -m "not slow" + bench gate
#   CI_SLOW=1 scripts/ci.sh  # also run the slow end-to-end tier
#
# The bench gate re-runs bench_step / bench_fleet / bench_attention and
# compares against the committed BENCH_step.json / BENCH_fleet.json /
# BENCH_attention.json (scripts/check_bench_regression.py; >25% step-time
# regression fails — CPU boxes are noisy, the precise trend lives in the
# committed snapshots).
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

python -m pytest -q -m "not slow"
if [[ "${CI_SLOW:-0}" == "1" ]]; then
    python -m pytest -q -m slow
fi
# spec validation + system registry smoke over the committed comparison spec
python scripts/run_experiment.py examples/specs/compare_smoke.json --dry-run
# seeded chaos smoke: drops/corruption/duplicates/torn writes injected at
# the transport + storage boundaries; the run must complete (retries +
# quorum absorb the faults) on a tiny vit in well under 30s.  The spec
# enables observability, so the run emits a Perfetto trace.json + CRC'd
# spans.jsonl per system — validate them (schema + CRCs) and require
# nonzero retry spans, proving fault injection exercised the retry path.
CHAOS_DIR=$(mktemp -d)
python scripts/run_experiment.py examples/specs/chaos_smoke.json \
    --results-dir "$CHAOS_DIR"
python scripts/trace_report.py "$CHAOS_DIR" --validate --require-retries \
    --out "$CHAOS_DIR/report.md"
rm -rf "$CHAOS_DIR"
python -m benchmarks.run --gate
