#!/usr/bin/env python
"""Render a Markdown round-by-round report from traced-run artifacts.

    PYTHONPATH=src python scripts/trace_report.py RESULTS_DIR
    PYTHONPATH=src python scripts/trace_report.py spans.jsonl --out report.md
    PYTHONPATH=src python scripts/trace_report.py RESULTS_DIR --validate \
        --require-retries          # CI mode: exit nonzero on problems

``RESULTS_DIR`` is an experiment results directory (per-system
subdirectories each holding ``spans.jsonl`` + ``trace.json``, as written
by :func:`repro.observability.export.export_artifacts`), a single system
directory, or a span JSONL file directly.

The report covers, per system: a track/event overview, a round-by-round
table built from the runner's phase spans (wall vs simulated duration,
loss/accuracy attributes), the transport ledger (sends, retries, fault
verdicts, backoff, exclusions), and the scheduler's sim-domain rounds.

``--validate`` re-reads every artifact strictly: span-log CRCs must
verify and the Chrome trace must pass
:func:`repro.observability.export.validate_chrome_trace`.
``--require-retries`` additionally fails when no transfer span recorded
a retry — the chaos-smoke CI gate uses it to prove fault injection
actually exercised the retry path.
"""

import argparse
import json
import os
import sys
from collections import Counter


def find_artifacts(path):
    """Yield ``(label, span_log_path, trace_json_path_or_None)``."""
    if os.path.isfile(path):
        label = os.path.basename(os.path.dirname(path)) or "run"
        sibling = os.path.join(os.path.dirname(path), "trace.json")
        return [(label, path, sibling if os.path.exists(sibling) else None)]
    direct = os.path.join(path, "spans.jsonl")
    if os.path.exists(direct):
        tj = os.path.join(path, "trace.json")
        return [(os.path.basename(os.path.normpath(path)) or "run",
                 direct, tj if os.path.exists(tj) else None)]
    found = []
    for name in sorted(os.listdir(path)):
        sub = os.path.join(path, name)
        sl = os.path.join(sub, "spans.jsonl")
        if os.path.isdir(sub) and os.path.exists(sl):
            tj = os.path.join(sub, "trace.json")
            found.append((name, sl, tj if os.path.exists(tj) else None))
    return found


def _fmt(v):
    if isinstance(v, float):
        return f"{v:.6g}"
    return str(v)


def _table(rows, cols):
    out = ["| " + " | ".join(cols) + " |",
           "|" + "|".join("---" for _ in cols) + "|"]
    for r in rows:
        out.append("| " + " | ".join(_fmt(r.get(c, "")) for c in cols)
                   + " |")
    return "\n".join(out)


def round_rows(spans):
    """Runner phase-step spans -> one row per (phase, step index)."""
    rows = []
    for e in spans:
        if e.kind != "span" or "." not in e.name:
            continue
        phase, _, step_name = e.name.partition(".")
        if step_name not in e.attrs:
            continue        # not a runner step span
        row = {"phase": phase, step_name: e.attrs[step_name],
               "step": e.attrs[step_name],
               "wall_s": round(e.dur_wall, 4),
               "sim_s": round(e.dur_sim, 4) if e.dur_sim is not None
               else ""}
        for k in ("loss", "val_loss", "val_acc", "buffered",
                  "staleness_max", "dropped"):
            if k in e.attrs:
                row[k] = e.attrs[k]
        rows.append(row)
    return rows


def transport_summary(spans):
    xfers = [e for e in spans if e.name == "xfer"]
    excluded = [e for e in spans
                if e.name == "excluded" and e.track == "transport"]
    verdicts = Counter()
    retries = failures = 0
    backoff = 0.0
    for e in xfers:
        a = e.attrs
        attempts = int(a.get("attempts", 1))
        if attempts > 1:
            retries += attempts - 1
        if not a.get("ok", True):
            failures += 1
        backoff += float(a.get("backoff_s", 0.0))
        for v in a.get("verdicts") or []:
            verdicts[v] += 1
    return {"sends": len(xfers), "retries": retries, "failures": failures,
            "excluded_devices": len(excluded),
            "backoff_s": round(backoff, 6), "verdicts": dict(verdicts)}


def scheduler_rows(spans):
    return [{"round": e.attrs.get("round"),
             "t_start_s": round(e.t_sim or 0.0, 3),
             "round_s": round(e.dur_sim or 0.0, 3),
             "clients": e.attrs.get("clients"),
             "dropped": e.attrs.get("dropped")}
            for e in spans
            if e.name == "round" and e.track.startswith("scheduler")]


def report_one(label, spans):
    tracks = Counter(e.track for e in spans)
    lines = [f"## {label}", "",
             f"{len(spans)} events across {len(tracks)} tracks: "
             + ", ".join(f"`{t}` ({n})" for t, n in sorted(tracks.items())),
             ""]
    rows = round_rows(spans)
    if rows:
        cols = ["phase", "step", "wall_s", "sim_s"]
        for extra in ("loss", "val_loss", "val_acc", "buffered",
                      "staleness_max", "dropped"):
            if any(extra in r for r in rows):
                cols.append(extra)
        lines += ["### Rounds", "", _table(rows, cols), ""]
    ts = transport_summary(spans)
    if ts["sends"]:
        lines += ["### Transport", "",
                  f"- sends: {ts['sends']}  retries: {ts['retries']}  "
                  f"failures: {ts['failures']}  excluded devices: "
                  f"{ts['excluded_devices']}",
                  f"- backoff total: {ts['backoff_s']}s",
                  f"- fault verdicts: "
                  + (", ".join(f"{k}={v}" for k, v in
                               sorted(ts["verdicts"].items())) or "none"),
                  ""]
    sched = scheduler_rows(spans)
    if sched:
        lines += ["### Scheduler rounds (sim clock)", "",
                  _table(sched, ["round", "t_start_s", "round_s",
                                 "clients", "dropped"]), ""]
    return "\n".join(lines), ts


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("path", help="results dir, system dir, or spans.jsonl")
    ap.add_argument("--out", default=None,
                    help="write the Markdown report here (default stdout)")
    ap.add_argument("--validate", action="store_true",
                    help="strict-verify span-log CRCs and the Chrome "
                         "trace schema; exit nonzero on any problem")
    ap.add_argument("--require-retries", action="store_true",
                    help="fail unless at least one transfer span "
                         "recorded a retry (chaos-smoke CI gate)")
    args = ap.parse_args(argv)

    from repro.observability.export import (read_span_log,
                                            validate_chrome_trace)

    artifacts = find_artifacts(args.path)
    if not artifacts:
        print(f"no span artifacts under {args.path!r}", file=sys.stderr)
        return 1

    problems = []
    total_retries = 0
    sections = ["# Trace report", ""]
    for label, span_path, trace_path in artifacts:
        try:
            spans = read_span_log(span_path, strict=args.validate)
        except (ValueError, OSError) as e:
            problems.append(f"{label}: {e}")
            continue
        if args.validate and trace_path is not None:
            with open(trace_path) as f:
                doc = json.load(f)
            problems.extend(f"{label}: {p}"
                            for p in validate_chrome_trace(doc))
        section, ts = report_one(label, spans)
        total_retries += ts["retries"]
        sections.append(section)

    report = "\n".join(sections)
    if args.out:
        with open(args.out, "w") as f:
            f.write(report + "\n")
        print(f"wrote {args.out}")
    else:
        print(report)

    if args.require_retries and total_retries == 0:
        problems.append("--require-retries: no transfer span recorded a "
                        "retry (fault injection never hit the retry path?)")
    if problems:
        print("\nVALIDATION PROBLEMS:", file=sys.stderr)
        for p in problems:
            print(f"  - {p}", file=sys.stderr)
        return 1
    if args.validate:
        print(f"\nvalidation OK ({len(artifacts)} system(s))",
              file=sys.stderr)
    return 0


if __name__ == "__main__":
    sys.exit(main())
