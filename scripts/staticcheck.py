#!/usr/bin/env python
"""Static kernel-safety + determinism checks (src/repro/staticcheck).

    scripts/staticcheck.py                    # report all findings
    scripts/staticcheck.py --gate             # fail on NON-baselined ones
    scripts/staticcheck.py --format md --out STATICCHECK_report.md
    scripts/staticcheck.py --write-baseline   # accept current findings

The gate contract matches the bench gate: committed
``STATICCHECK_baseline.json`` carries accepted findings (each with a
reason string); only *new* findings fail CI, and stale baseline entries
are reported so the file never rots.  Kernel tracing is cached per
config/source hash in ``.staticcheck_cache.json`` (gitignored) —
``--no-cache`` forces a full re-trace.
"""

from __future__ import annotations

import argparse
import json
import os
import sys

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(REPO_ROOT, "src"))


def main(argv=None) -> int:
    from repro.staticcheck import (BASELINE_FILE, AnalyzerSettings, Baseline,
                                   BaselineEntry, format_json,
                                   format_markdown, format_text,
                                   run_staticcheck)

    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--gate", action="store_true",
                    help="exit 1 on findings not in the baseline")
    ap.add_argument("--format", choices=("text", "md", "json"),
                    default="text")
    ap.add_argument("--out", help="write the report here instead of stdout")
    ap.add_argument("--baseline",
                    default=os.path.join(REPO_ROOT, BASELINE_FILE))
    ap.add_argument("--write-baseline", action="store_true",
                    help="accept all current findings into the baseline "
                         "(reasons of unchanged entries are preserved; "
                         "new entries get a TODO reason to fill in)")
    ap.add_argument("--no-cache", action="store_true",
                    help="re-trace every kernel config")
    ap.add_argument("--no-kernels", action="store_true",
                    help="skip the Pallas kernel analyzer")
    ap.add_argument("--no-lint", action="store_true",
                    help="skip the determinism lint")
    ap.add_argument("--dma-threshold", type=int, default=2,
                    help="min acceptable aliased revisit distance "
                         "(default 2 — the tightest schedule the kernels "
                         "intentionally produce)")
    args = ap.parse_args(argv)

    settings = AnalyzerSettings(dma_safety_threshold=args.dma_threshold)
    findings, summaries = run_staticcheck(
        REPO_ROOT, kernels=not args.no_kernels, lint=not args.no_lint,
        use_cache=not args.no_cache, settings=settings)
    baseline = Baseline.load(args.baseline)
    gate = baseline.check(findings)

    if args.write_baseline:
        old = {e.fingerprint: e for e in baseline.entries}
        entries = []
        for f in findings:
            prev = old.get(f.fingerprint)
            entries.append(BaselineEntry(
                fingerprint=f.fingerprint, rule=f.rule, path=f.path,
                context=f.context,
                reason=prev.reason if prev is not None
                else "TODO: justify this acceptance"))
        Baseline(entries).save(args.baseline)
        print(f"wrote {len(entries)} accepted finding(s) to "
              f"{args.baseline}")
        return 0

    if args.format == "md":
        report = format_markdown(findings, gate, summaries)
    elif args.format == "json":
        report = format_json(findings, gate)
    else:
        report = format_text(findings, gate) if findings else ""
    if args.out:
        with open(args.out, "w") as f:
            f.write(report + ("\n" if report and not report.endswith("\n")
                              else ""))
    elif report:
        print(report)

    n_err = sum(1 for f in findings if f.severity == "error")
    status = {"findings": len(findings), "errors": n_err,
              "new": len(gate.new), "baselined": len(gate.accepted),
              "stale_baseline": len(gate.stale)}
    print(f"staticcheck: {json.dumps(status, sort_keys=True)}",
          file=sys.stderr)

    if not args.gate:
        return 0
    if gate.stale:
        print(f"staticcheck: WARNING {len(gate.stale)} stale baseline "
              "entr(ies) — findings no longer present; regenerate with "
              "--write-baseline", file=sys.stderr)
    if gate.new:
        print(f"staticcheck: FAIL — {len(gate.new)} new finding(s) not in "
              f"{os.path.basename(args.baseline)}:", file=sys.stderr)
        for f in gate.new:
            print(f.format(), file=sys.stderr)
        print("either fix them, waive at the code site "
              "(# staticcheck: ok=<rule> <reason>), or accept into the "
              "baseline with --write-baseline + a reason string.",
              file=sys.stderr)
        return 1
    print("staticcheck: gate OK", file=sys.stderr)
    return 0


if __name__ == "__main__":
    sys.exit(main())
