#!/usr/bin/env python
"""Compare two BENCH_step.json snapshots; fail on step-time regression.

    python scripts/check_bench_regression.py baseline.json candidate.json \
        [--threshold 0.10]

Exits nonzero when any entry of ``times_s`` in the candidate is more than
``threshold`` (default 10%) slower than the baseline.  Entries present in
only one file are reported but never fail the check (benchmarks may be
added or renamed between PRs).
"""

from __future__ import annotations

import argparse
import json
import sys


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("baseline")
    ap.add_argument("candidate")
    ap.add_argument("--threshold", type=float, default=0.10,
                    help="allowed fractional slowdown (0.10 = 10%%)")
    args = ap.parse_args(argv)

    with open(args.baseline) as f:
        base = json.load(f)["times_s"]
    with open(args.candidate) as f:
        cand = json.load(f)["times_s"]

    failures = []
    for name in sorted(set(base) | set(cand)):
        if name not in base or name not in cand:
            print(f"[skip] {name}: only in "
                  f"{'candidate' if name in cand else 'baseline'}")
            continue
        b, c = float(base[name]), float(cand[name])
        ratio = c / b if b > 0 else float("inf")
        status = "ok"
        if ratio > 1.0 + args.threshold:
            status = "REGRESSION"
            failures.append(name)
        print(f"[{status}] {name}: {b:.6f}s -> {c:.6f}s ({ratio:.3f}x)")

    if failures:
        print(f"\n{len(failures)} regression(s) beyond "
              f"{args.threshold:.0%}: {failures}")
        return 1
    print("\nno step-time regressions")
    return 0


if __name__ == "__main__":
    sys.exit(main())
