#!/usr/bin/env python
"""Declarative experiment runner.

    PYTHONPATH=src python scripts/run_experiment.py SPEC.json
    PYTHONPATH=src python scripts/run_experiment.py SPEC.json --dry-run

One spec file drives every listed system (Ampere, SFL family, FedAvg)
over one shared setup — same model init, same non-IID partition, and
(when the spec carries a fleet section) one shared JSONL fleet trace —
writing a single results directory with ``summary.json`` plus
per-system history files.

``--dry-run`` validates the spec, resolves every system from the
registry, and reports the plan without building a model; CI uses it to
exercise spec validation and the registry on every run.
"""

import argparse
import json
import sys


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("spec", help="ExperimentSpec JSON file")
    ap.add_argument("--dry-run", action="store_true",
                    help="validate the spec + registry, print the plan, "
                         "run nothing")
    ap.add_argument("--results-dir", default=None,
                    help="override spec.results_dir")
    ap.add_argument("--echo", action="store_true",
                    help="echo per-round metrics lines")
    ap.add_argument("--profile", action="store_true",
                    help="wrap the run in jax.profiler.trace and couple "
                         "spans to TraceAnnotation (forces observability "
                         "on; XLA dump lands under <results>/profile)")
    ap.add_argument("--role", choices=("device", "server"), default=None,
                    help="two-process socket mode: run only this side of "
                         "the Ampere pipeline (see repro.transport.roles)")
    ap.add_argument("--host", default=None,
                    help="socket mode: override spec.transport.host")
    ap.add_argument("--port", type=int, default=None,
                    help="socket mode: override spec.transport.port")
    args = ap.parse_args(argv)

    from repro.configs.base import replace
    from repro.experiments import ExperimentSpec, run_experiment

    spec = ExperimentSpec.load(args.spec)
    if args.results_dir is not None:
        spec = replace(spec, results_dir=args.results_dir)
    if args.profile:
        from repro.experiments import ObservabilitySpec
        obs_spec = spec.observability or ObservabilitySpec()
        spec = replace(spec, observability=replace(
            obs_spec, enabled=True, profile=True))

    problems = spec.validate()
    if problems:
        print(f"INVALID spec {args.spec}:", file=sys.stderr)
        for p in problems:
            print(f"  - {p}", file=sys.stderr)
        return 1

    if args.role is not None:
        from repro.transport import roles
        if args.role == "device":
            out = roles.run_device_role(spec, host=args.host,
                                        port=args.port, echo=args.echo)
        else:
            out = roles.run_server_role(spec, host=args.host,
                                        port=args.port, echo=args.echo,
                                        results_dir=args.results_dir)
        print(json.dumps(out.get("summary") or out.get("result"), indent=1))
        return 0

    if args.dry_run:
        out = run_experiment(spec, dry_run=True)
        plan = {
            "spec": args.spec,
            "name": spec.name,
            "arch": spec.arch + (" (smoke)" if spec.smoke else ""),
            "systems": out["systems"],
            "rounds": spec.max_rounds or spec.run.fed.device_epochs,
            "server_epochs": (spec.max_server_epochs
                              or spec.run.fed.server_epochs),
            "clients": spec.run.fed.num_clients,
            "trace": spec.trace_path or ("<simulated from fleet cfg>"
                                         if spec.fleet else None),
            "results_dir": spec.results_dir or f"results/{spec.name}",
        }
        print(json.dumps(plan, indent=1))
        print("dry-run OK")
        return 0

    if args.profile:
        import os
        from repro.observability.profiling import profile_run
        logdir = os.path.join(
            spec.results_dir or f"results/{spec.name}", "profile")
        with profile_run(logdir):
            out = run_experiment(spec, log_echo=args.echo)
        print(f"profiler trace (if jax.profiler is available): {logdir}")
    else:
        out = run_experiment(spec, log_echo=args.echo)
    print(json.dumps(out["summary"], indent=1))
    if spec.observability is not None and spec.observability.enabled:
        from repro.observability.metrics import format_phase_table
        for name, system in sorted(out["summary"].items()):
            rows = system.get("phases")
            if rows:
                print()
                print(format_phase_table(rows, title=name))
    print(f"wrote {out['results_dir']}/summary.json")
    return 0


if __name__ == "__main__":
    sys.exit(main())
