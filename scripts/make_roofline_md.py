"""Render the §Roofline markdown table from results/dryrun/merged.json and
inject it into EXPERIMENTS.md at the <!-- ROOFLINE_TABLE --> marker."""

import json
import sys

SRC = sys.argv[1] if len(sys.argv) > 1 else "results/dryrun/merged.json"
DST = "EXPERIMENTS.md"

with open(SRC) as f:
    rows = json.load(f)

hdr = ("| arch | shape | step | t_comp (ms) | t_mem (ms) | t_coll (ms) | "
       "bottleneck | useful | roofline frac | peak GB/dev | multi-pod |\n")
sep = "|" + "---|" * 11 + "\n"

by_key = {}
for r in rows:
    if r.get("status") == "ok":
        by_key[(r["arch"], r["shape"], r["mesh"])] = r
    elif r.get("status") == "skip":
        by_key[(r["arch"], r["shape"], "skip")] = r

lines = [hdr, sep]
archs, shapes = [], ["train_4k", "prefill_32k", "decode_32k", "long_500k"]
for r in rows:
    if r["arch"] not in archs:
        archs.append(r["arch"])
for arch in archs:
    for shape in shapes:
        if (arch, shape, "skip") in by_key:
            lines.append(f"| {arch} | {shape} | — | — | — | — | "
                         f"SKIP (full-attn @500k) | — | — | — | — |\n")
            continue
        r = by_key.get((arch, shape, "single_pod"))
        if r is None:
            continue
        mp = by_key.get((arch, shape, "multi_pod"))
        mp_s = "ok" if mp else "—"
        lines.append(
            f"| {arch} | {shape} | {r['step'].replace('_step','')} "
            f"| {r['t_compute_ms']:.1f} | {r['t_memory_ms']:.1f} "
            f"| {r['t_collective_ms']:.1f} | {r['bottleneck']} "
            f"| {r['useful_flops_frac']:.2f} | {r['roofline_frac']:.3f} "
            f"| {r['peak_mem_gb_per_device']:.1f} | {mp_s} |\n")

table = "".join(lines)
with open(DST) as f:
    doc = f.read()
marker = "<!-- ROOFLINE_TABLE -->"
doc = doc.replace(marker, table)
with open(DST, "w") as f:
    f.write(doc)
print(f"injected {len(lines)-2} rows into {DST}")
