"""Perf trajectory benchmark: times the roofline-bearing step path.

This is the repo's perf regression gate — every perf PR reruns it and
compares against the committed ``BENCH_step.json`` via
``scripts/check_bench_regression.py`` (>10% step-time regression fails).

  PYTHONPATH=src python -m benchmarks.run --only bench_step

Measured (CPU smoke scale here; the same code paths run at production
scale on the pod launcher):

* ``xent_fwd`` / ``xent_grad`` — fused cross-entropy Pallas kernel,
  forward and single-sweep fused backward (dH + dW in one grid sweep).
* ``server_step``       — one jitted server-phase training step.
* ``server_epoch_loop`` — the pre-PR host loop: per-batch ``jnp.asarray``
  upload + per-batch ``float(loss)`` sync.
* ``server_epoch_jit``  — device-resident pool + one donated
  ``lax.scan`` epoch, one host sync per epoch.
* ``device_round``      — one jitted federated device round.

Output ``BENCH_step.json`` fields:

* ``config``   — shapes / arch / batch sizes measured.
* ``times_s``  — best-of-``reps`` wall-clock seconds per entry above.
* ``phase_medians_s`` — median-of-``reps`` seconds per pipeline phase
  (device_round / consolidate / server_epoch); the steady-state figure
  matching the observability phase table, reported but never gated.
* ``speedup_epoch`` — server_epoch_loop / server_epoch_jit.
* ``streaming_overlap_speedup`` — serialized server-epoch sim-time over
  the ring-pipelined accounted sim-time when the upload goes through the
  activation ring and epochs overlap the device round
  (:mod:`repro.streaming`); an analytic pipeline figure, never gated.
"""

from __future__ import annotations

import json

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import (best as _best, samples as _samples, save,
                               setup_fed_run, table)

BENCH_PATH = "BENCH_step.json"


def _bench_xent(reps: int):
    from repro.kernels.xent.kernel import fused_xent_pallas

    T, D, V = 128, 64, 1024
    rng = np.random.default_rng(0)
    h = jnp.asarray(rng.normal(0, 1, (T, D)), jnp.float32)
    w = jnp.asarray(rng.normal(0, 1, (D, V)) / np.sqrt(D), jnp.float32)
    lab = jnp.asarray(rng.integers(0, V, (T,)), jnp.int32)

    fwd = jax.jit(lambda h, w: jnp.mean(fused_xent_pallas(h, w, lab)))
    grad = jax.jit(jax.grad(
        lambda h, w: jnp.mean(fused_xent_pallas(h, w, lab)), argnums=(0, 1)))
    fwd(h, w).block_until_ready()                       # compile
    jax.block_until_ready(grad(h, w))
    return {
        "xent_fwd": _best(lambda: fwd(h, w).block_until_ready(), reps),
        "xent_grad": _best(lambda: jax.block_until_ready(grad(h, w)), reps),
    }, {"xent_T": T, "xent_D": D, "xent_V": V}


def _streaming_overlap_speedup(tr, dev_state, epochs: int = 3) -> float:
    """Serialized server-epoch sim-time over the ring-pipelined
    accounted sim-time for the same ``epochs`` (analytic — no extra
    wall-clock measurement).  >1 means the streaming learner hid part
    of the server phase behind the still-running device upload."""
    from repro.core import comm_model
    from repro.streaming import OverlapAccountant, StreamingActivationStore

    store = StreamingActivationStore(backend="memory", seed=0)
    tr.generate_activations(dev_state, store)
    bs = tr.run.fed.server_batch_size
    epoch_sim = comm_model.ampere_server_epoch_time(
        tr.model, tr.run.split, comm_model.TimeModel(),
        n_samples=store.num_samples(), seq_len=tr._seq_len(),
        sizes=tr.sizes)
    nb = max(1, store.num_samples() // bs)
    acct = OverlapAccountant(store.sample_arrivals(),
                             device_end=tr._transfer_sim_s,
                             per_batch_s=epoch_sim / nb)
    accounted = 0.0
    for _ in range(epochs):
        dt, _ = acct.epoch(store.epoch_indices(bs))
        accounted += dt
    # fully-hidden epochs account 0s; floor at one batch-time so the
    # ratio stays finite (caps the speedup at epochs * batches)
    return epochs * epoch_sim / max(accounted, epoch_sim / nb)


def _bench_server_and_round(reps: int):
    from repro.core import steps
    from repro.core.uit import AmpereTrainer
    from repro.data import ActivationStore
    from repro.data.pipeline import round_batches

    arch = "mobilenet-l"
    model, run_cfg, clients, evald = setup_fed_run(
        arch, clients=4, cohort=2, local_steps=2, batch=4,
        n_train=512, n_eval=64)
    tr = AmpereTrainer(model, run_cfg, clients, evald, patience=100)
    dev, srv, aux = tr._init_states(jax.random.PRNGKey(0))
    dev_state = {"device": dev, "aux": aux}
    store = ActivationStore(seed=0)
    tr.generate_activations(dev_state, store)
    bs = run_cfg.fed.server_batch_size

    # one jitted server step
    step = jax.jit(steps.make_server_train_step(model, run_cfg))
    st = steps.init_server_state(model, run_cfg, srv)
    batch0 = {k: jnp.asarray(v)
              for k, v in next(iter(store.batches(bs, epochs=1))).items()}
    st, _ = step(st, batch0)                            # compile
    jax.block_until_ready(st)

    def one_step():
        s2, m = step(st, batch0)
        jax.block_until_ready(s2)

    # seed-style per-batch epoch loop (host upload + float() every step);
    # state chains across reps exactly like real training
    loop_state = [st]

    def epoch_loop():
        s2 = loop_state[0]
        for batch in store.batches(bs, epochs=1):
            batch = {k: jnp.asarray(v) for k, v in batch.items()}
            s2, m = step(s2, batch)
            float(m["loss"])
        loop_state[0] = s2

    # device-resident donated jitted epoch (this PR's path)
    epoch_jit = jax.jit(steps.make_server_epoch_fn(model, run_cfg),
                        donate_argnums=(0,))
    pool = {k: jnp.asarray(v)
            for k, v in store.pool(dequantize=False).items()}
    jit_state = [jax.tree.map(lambda a: jnp.array(a),
                              steps.init_server_state(model, run_cfg, srv))]
    idx0 = jnp.asarray(store.epoch_indices(bs))
    s2, l = epoch_jit(jit_state[0], pool, idx0)         # compile
    np.asarray(l)
    jit_state[0] = s2

    def epoch_jitted():
        idx = jnp.asarray(store.epoch_indices(bs))
        s2, losses = epoch_jit(jit_state[0], pool, idx)
        np.asarray(losses)
        jit_state[0] = s2

    # one federated device round (the jitted step donates its input
    # state, so the state chains across reps like real training)
    fed = run_cfg.fed
    ids = list(range(fed.clients_per_round))
    batches = round_batches(clients, ids, fed.local_steps,
                            fed.device_batch_size)
    batches = {k: jnp.asarray(v) for k, v in batches.items()}
    w = jnp.ones((fed.clients_per_round,), jnp.float32)
    round_state = [jax.tree.map(lambda a: jnp.array(a), dev_state)]
    s2, _ = tr._device_round(round_state[0], batches, w, 0.1)
    jax.block_until_ready(s2)
    round_state[0] = s2

    def one_round():
        s2, m = tr._device_round(round_state[0], batches, w, 0.1)
        jax.block_until_ready(s2)
        round_state[0] = s2

    # per-phase samples: best-of feeds the regression gate (times_s),
    # the median of the same samples lands in phase_medians_s — the
    # steady-state per-phase figure the observability phase table
    # reports for real runs (best-of hides warm-cache outliers)
    def consolidate():
        tr.generate_activations(dev_state, ActivationStore(seed=0))

    phase_samples = {
        "device_round": _samples(one_round, reps),
        "server_epoch": _samples(epoch_jitted, reps),
        "consolidate": _samples(consolidate, reps),
    }
    medians = {k: float(np.median(v)) for k, v in phase_samples.items()}
    overlap_speedup = _streaming_overlap_speedup(tr, dev_state)
    times = {
        "server_step": _best(one_step, reps),
        "server_epoch_loop": _best(epoch_loop, reps),
        "server_epoch_jit": min(phase_samples["server_epoch"]),
        "device_round": min(phase_samples["device_round"]),
    }
    cfg = {"arch": arch, "server_batch": bs,
           "pool_samples": store.num_samples(),
           "device_batch": fed.device_batch_size,
           "local_steps": fed.local_steps,
           "cohort": fed.clients_per_round,
           "backend": jax.default_backend()}
    return times, cfg, medians, overlap_speedup


def run(quick: bool = True):
    reps = 3 if quick else 10
    times, config = {}, {}
    t, c = _bench_xent(reps)
    times.update(t)
    config.update(c)
    t, c, medians, overlap_speedup = _bench_server_and_round(reps)
    times.update(t)
    config.update(c)

    speedup = times["server_epoch_loop"] / times["server_epoch_jit"]
    payload = {"config": config,
               "times_s": {k: round(v, 6) for k, v in times.items()},
               # median-of-reps per pipeline phase; reported alongside the
               # best-of gate numbers, never gated on (noisier statistic)
               "phase_medians_s": {k: round(v, 6)
                                   for k, v in medians.items()},
               "speedup_epoch": round(speedup, 3),
               # analytic sim-time ratio from the streaming overlap model
               # (serialized transfer+epochs vs ring-pipelined); not gated
               "streaming_overlap_speedup": round(overlap_speedup, 6)}
    with open(BENCH_PATH, "w") as f:
        json.dump(payload, f, indent=1)
        f.write("\n")
    save("bench_step", payload)

    rows = [{"metric": k, "seconds": v} for k, v in times.items()]
    rows += [{"metric": f"{k} (median)", "seconds": v}
             for k, v in medians.items()]
    rows.append({"metric": "epoch speedup (loop/jit)", "seconds": speedup})
    rows.append({"metric": "streaming overlap speedup (sim)",
                 "seconds": overlap_speedup})
    table(rows, ["metric", "seconds"], "bench_step — step-path wall clock")
    return payload


if __name__ == "__main__":
    run(quick=False)
