"""Paper Fig. 3 + Fig. 6: the split-point trade-off.

Fig. 3 (BP/SFL): per-round communication INCLUDES per-iteration
activations+gradients — minimized at a *late* split point, while on-device
compute is minimized at p=1: the trade-off Ampere eliminates.
Fig. 6 (UIT/Ampere): communication is model exchanges + one-shot
activations — the model term and compute BOTH grow with p, so p=1 is
simultaneously optimal.
"""

from __future__ import annotations

import numpy as np

from benchmarks.common import gb, save, table
from repro.configs import registry
from repro.configs.base import SplitConfig
from repro.core import comm_model
from repro.models import build_model

SAMPLES_PER_ROUND = 10_000   # paper: 10k local samples per round
EPOCHS = 100


def run(quick: bool = True):
    model = build_model(registry.get_config("mobilenet-l"))
    L = model.num_layers
    rows = []
    for p in range(1, L + 1):
        sc = SplitConfig(split_point=p)
        sizes = comm_model.split_sizes(model, sc)
        act_round = sizes.act_per_sample * SAMPLES_PER_ROUND
        bp_comm = 2 * sizes.device + 2 * act_round          # per round
        # UIT per-round comm = model exchanges; the one-shot activation
        # transfer is NOT per-round (paper §3.2.1: negligible for N>=100;
        # reported separately as act_oneshot_GB)
        uit_comm = 2 * (sizes.device + sizes.aux)
        dev_gflops_bp = comm_model.device_flops_per_sample(
            model, sc, "splitfed") * SAMPLES_PER_ROUND / 1e9
        dev_gflops_uit = comm_model.device_flops_per_sample(
            model, sc, "ampere") * SAMPLES_PER_ROUND / 1e9
        rows.append({"p": p,
                     "bp_comm_GB": gb(bp_comm),
                     "uit_comm_GB": gb(uit_comm),
                     "act_oneshot_GB": gb(act_round),
                     "bp_device_GFLOPs": dev_gflops_bp,
                     "uit_device_GFLOPs": dev_gflops_uit})
    table(rows[:6] + rows[-2:],
          ["p", "bp_comm_GB", "uit_comm_GB", "bp_device_GFLOPs",
           "uit_device_GFLOPs"],
          "Fig 3/6 — split-point sweep (MobileNet-L; first 6 + last 2 rows)")
    save("fig3_fig6_splitpoint", rows)

    # Fig. 3 property: BP comm is NOT minimized at p=1 (activations shrink
    # deeper in the net) while compute IS minimized at p=1.
    bp_comm = [r["bp_comm_GB"] for r in rows]
    assert int(np.argmin(bp_comm)) > 0
    assert rows[0]["bp_device_GFLOPs"] == min(r["bp_device_GFLOPs"]
                                              for r in rows)
    # Fig. 6 property: UIT model-exchange-dominated comm and compute are
    # both minimized at p=1 — no trade-off.
    assert rows[0]["uit_comm_GB"] == min(r["uit_comm_GB"] for r in rows)
    assert rows[0]["uit_device_GFLOPs"] == min(r["uit_device_GFLOPs"]
                                               for r in rows)
    print("Fig3: BP comm optimum at p="
          f"{int(np.argmin(bp_comm)) + 1}, compute optimum at p=1 "
          "(trade-off).  Fig6: UIT both optima at p=1 (eliminated).")
    return rows


if __name__ == "__main__":
    run()
