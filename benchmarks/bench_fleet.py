"""Fleet-subsystem perf gate: scheduler hot loop + vmapped cohort rounds.

Writes ``BENCH_fleet.json`` at the repo root (same contract as
``BENCH_step.json``: ``times_s`` entries are gated by
``scripts/check_bench_regression.py``).

Measured:

* ``sched_512dev_100rounds`` — wall clock for the discrete-event scheduler
  to simulate 100 rounds over a churning 512-device population (the
  coordinator hot loop: heap ops, cohort selection, heartbeat/churn
  events); ``events_per_sec`` lands in the payload for trend reading.
* ``sched_async_512dev_100rounds`` — same population through the buffered
  semi-synchronous (FedBuff) mode: 100 aggregations of a 32-update buffer
  with 64 concurrent devices.  ``async_sim_speedup`` compares the two
  modes' *simulated* wall clocks over the same straggler-heavy population
  (sync closes each round on the slowest survivor; async overlaps them) —
  the fleet-level number the async mode exists for.
* ``fleet_round_vmap_k16`` / ``fleet_round_loop_k16`` (and _k64) — one
  federated cohort round through the vmapped pool-fed step vs. the naive
  Python per-client loop (per-client batch gather + jitted single-client
  round + host FedAvg).  ``speedup_k16`` / ``speedup_k64`` = loop / vmap;
  ``loss_absdiff_k16`` documents the fp-level equivalence of the two paths.

  Measured on ``vit-s`` (a paper vision arch): vmapping per-client params
  turns its matmuls into efficient batched matmuls.  Caveat worth knowing:
  per-client *conv* weights (mobilenet/vgg) lower to grouped convolutions,
  which XLA *CPU* executes so poorly that the loop wins there — on
  TPU/GPU the grouped form is fine.  See fleet/README.md.

  PYTHONPATH=src python -m benchmarks.run --only bench_fleet
"""

from __future__ import annotations

import json

import jax
import numpy as np

from benchmarks.common import best as _best, save, setup_fed_run, table

BENCH_PATH = "BENCH_fleet.json"


def _bench_scheduler(reps: int):
    import dataclasses

    from repro.fleet import (FleetConfig, FleetScheduler, sample_population)

    cfg = FleetConfig(n_devices=512, seed=0, dropout_hazard=0.03,
                      deadline_factor=2.5, target_round_time_factor=1.5,
                      min_cohort=8, max_cohort=64, init_cohort=32)
    pop = sample_population(cfg)
    lat = lambda p: 1.0 / p.speed_factor       # noqa: E731 — time-only bench
    sched = FleetScheduler(pop, lat, cfg)
    n_rounds = 100
    trace = sched.simulate(n_rounds)           # warm-up + event count
    n_events = len(trace.events)
    t = _best(lambda: sched.simulate(n_rounds), reps)

    # buffered semi-synchronous mode over the same population: straggler
    # deadline off in BOTH modes so the sim-time comparison isolates the
    # aggregation discipline (sync waits for the slowest survivor, async
    # aggregates every 32 completions)
    sync_cfg = dataclasses.replace(cfg, deadline_factor=0.0,
                                   target_round_time_factor=0.0)
    async_cfg = dataclasses.replace(sync_cfg, async_buffer_size=32,
                                    max_staleness=8, max_concurrent=64)
    sync_trace = FleetScheduler(pop, lat, sync_cfg).simulate(n_rounds)
    a_sched = FleetScheduler(pop, lat, async_cfg)
    async_trace = a_sched.simulate(n_rounds)
    t_async = _best(lambda: a_sched.simulate(n_rounds), reps)
    return ({"sched_512dev_100rounds": t,
             "sched_async_512dev_100rounds": t_async},
            {"sched_devices": 512, "sched_rounds": n_rounds,
             "sched_events": n_events,
             "events_per_sec": int(n_events / t),
             "sync_sim_total_s": round(sync_trace.total_time, 6),
             "async_sim_total_s": round(async_trace.total_time, 6),
             "async_sim_speedup": round(
                 sync_trace.total_time / async_trace.total_time, 3)})


def _bench_rounds(reps: int):
    from repro.fleet import FleetEngine

    K = 64
    arch = "vit-s"
    model, run_cfg, clients, _ = setup_fed_run(
        arch, clients=K, cohort=K, local_steps=2, batch=8,
        n_train=1024, n_eval=64)
    engine = FleetEngine(model, run_cfg, clients, seed=0, donate=False)
    tr_key = jax.random.PRNGKey(0)
    params = model.init(tr_key)
    from repro.core import auxiliary, splitting
    dev, _ = splitting.split_params(model, params, run_cfg.split.split_point)
    aux = auxiliary.init_aux(model, jax.random.fold_in(tr_key, 7),
                             run_cfg.split)
    state = {"device": dev, "aux": aux}

    times, extras = {}, {}
    for k in (16, 64):
        ids = list(range(k))
        w = [1.0 / k] * k

        def vmap_round():
            s, m = engine.run_round(state, 0, ids, w, 0.1)
            jax.block_until_ready(s)
            return m

        def loop_round():
            s, m = engine.sequential_round(state, 0, ids, w, 0.1)
            jax.block_until_ready(s)
            return m

        mv = vmap_round()                       # compile
        ml = loop_round()
        times[f"fleet_round_vmap_k{k}"] = _best(vmap_round, reps)
        times[f"fleet_round_loop_k{k}"] = _best(loop_round, reps)
        extras[f"speedup_k{k}"] = round(
            times[f"fleet_round_loop_k{k}"] / times[f"fleet_round_vmap_k{k}"],
            3)
        if k == 16:
            extras["loss_absdiff_k16"] = float(
                abs(float(mv["loss"]) - float(ml["loss"])))
    cfg = {"arch": arch, "local_steps": run_cfg.fed.local_steps,
           "device_batch": run_cfg.fed.device_batch_size,
           "pool_samples": int(sum(len(c) for c in clients)),
           "backend": jax.default_backend()}
    return times, dict(cfg, **extras)


def run(quick: bool = True):
    reps = 3 if quick else 10
    times, config = {}, {}
    t, c = _bench_scheduler(reps)
    times.update(t)
    config.update(c)
    t, c = _bench_rounds(reps)
    times.update(t)
    config.update(c)

    payload = {"config": config,
               "times_s": {k: round(v, 6) for k, v in times.items()},
               "speedup_k16": config.pop("speedup_k16"),
               "speedup_k64": config.pop("speedup_k64"),
               "events_per_sec": config.pop("events_per_sec"),
               "async_sim_speedup": config.pop("async_sim_speedup"),
               "loss_absdiff_k16": config.pop("loss_absdiff_k16")}
    with open(BENCH_PATH, "w") as f:
        json.dump(payload, f, indent=1)
        f.write("\n")
    save("bench_fleet", payload)

    rows = [{"metric": k, "value": v} for k, v in times.items()]
    rows += [{"metric": "speedup k16 (loop/vmap)",
              "value": payload["speedup_k16"]},
             {"metric": "speedup k64 (loop/vmap)",
              "value": payload["speedup_k64"]},
             {"metric": "scheduler events/sec",
              "value": payload["events_per_sec"]},
             {"metric": "async sim speedup (sync/async wall clock)",
              "value": payload["async_sim_speedup"]}]
    table(rows, ["metric", "value"], "bench_fleet — fleet-path wall clock")
    return payload


if __name__ == "__main__":
    run(quick=False)
