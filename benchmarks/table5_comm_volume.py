"""Paper Table 5: total device-server communication per device to
convergence, for every baseline system — paper archs AND the assigned LM
archs (exact analytic accounting; epoch counts follow Table 4's measured
convergence pattern: Ampere's device phase converges in ~1/2 to 1/4 the
epochs of SFL's end-to-end training)."""

from __future__ import annotations

from benchmarks.common import gb, save, table
from repro.configs import registry
from repro.configs.base import SplitConfig
from repro.core import comm_model
from repro.models import build_model

# epochs-to-convergence (from Table 4, MobileNet-L CIFAR-10 column)
EPOCHS = {"splitfed": 200, "pipar": 210, "scaffold": 240, "splitgp": 300,
          "fedavg": 200, "ampere": (55, 32)}  # (device, server)
N_SAMPLES = 10_000


def run(quick: bool = True):
    archs = ["mobilenet-l", "vgg11", "swin-t", "vit-s"]
    if not quick:
        archs += ["qwen3-1.7b", "gemma2-2b", "mamba2-370m"]
    rows = []
    for arch in archs:
        model = build_model(registry.get_config(arch))
        seq = 4096 if model.kind == "lm" else 0
        sizes = comm_model.split_sizes(model, SplitConfig(split_point=1),
                                       seq_len=seq)
        row = {"model": arch}
        for algo in ("fedavg", "splitfed", "pipar", "scaffold", "splitgp",
                     "ampere"):
            if algo == "ampere":
                nd, _ = EPOCHS["ampere"]
                vol = comm_model.comm_volume("ampere", sizes, epochs=nd,
                                             n_samples=N_SAMPLES,
                                             device_epochs=nd)
            else:
                vol = comm_model.comm_volume(algo, sizes,
                                             epochs=EPOCHS[algo],
                                             n_samples=N_SAMPLES)
            row[algo + "_GB"] = gb(vol)
        rows.append(row)
        # headline claim: Ampere ~99% below every SFL baseline
        for algo in ("splitfed", "pipar", "scaffold", "splitgp"):
            assert row["ampere_GB"] < 0.15 * row[algo + "_GB"], (arch, algo)
    cols = ["model"] + [a + "_GB" for a in
                        ("fedavg", "splitfed", "pipar", "scaffold",
                         "splitgp", "ampere")]
    table(rows, cols, "Table 5 — comm volume per device to convergence (GB)")
    reduction = max(1 - r["ampere_GB"] / r["splitfed_GB"] for r in rows)
    print(f"max comm reduction vs SplitFed: {100*reduction:.1f}% "
          "(paper: up to 99.1%)")
    save("table5_comm_volume", rows)
    return rows


if __name__ == "__main__":
    run()
