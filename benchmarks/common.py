"""Shared benchmark utilities."""

from __future__ import annotations

import json
import os
import time

import numpy as np

RESULTS_DIR = os.environ.get("REPRO_RESULTS", "results/bench")


def samples(fn, reps: int) -> list:
    """All ``reps`` wall-clock samples for ``fn()`` (median reporting)."""
    ts = []
    for _ in range(reps):
        t0 = time.perf_counter()
        fn()
        ts.append(time.perf_counter() - t0)
    return ts


def best(fn, reps: int) -> float:
    """Best-of-``reps`` wall-clock seconds for ``fn()`` (perf gates)."""
    return min(samples(fn, reps))


def save(name: str, payload):
    os.makedirs(RESULTS_DIR, exist_ok=True)
    path = os.path.join(RESULTS_DIR, f"{name}.json")
    with open(path, "w") as f:
        json.dump(payload, f, indent=1, default=_to_py)
    return path


def _to_py(x):
    if isinstance(x, (np.floating, np.integer)):
        return x.item()
    if isinstance(x, np.ndarray):
        return x.tolist()
    return str(x)


def table(rows, cols, title=""):
    """Print a markdown table."""
    if title:
        print(f"\n### {title}")
    print("| " + " | ".join(cols) + " |")
    print("|" + "---|" * len(cols))
    for r in rows:
        cells = []
        for c in cols:
            v = r.get(c, "")
            if isinstance(v, float):
                v = f"{v:.4g}"
            cells.append(str(v))
        print("| " + " | ".join(cells) + " |")
    print(flush=True)


def gb(x) -> float:
    return x / 1e9


def setup_fed_run(arch: str, *, algo="ampere", alpha=0.33, clients=8,
                  cohort=4, local_steps=8, batch=16, lr=0.2,
                  n_train=1536, n_eval=384, seq_len=48, seed=0):
    """Build (model, run_cfg, clients, eval) at smoke scale."""
    from repro.configs import registry
    from repro.configs.base import FedConfig, OptimConfig, RunConfig
    from repro.data import federate, make_dataset_for_model
    from repro.models import build_model

    cfg = registry.get_smoke_config(arch)
    model = build_model(cfg)
    run_cfg = RunConfig(
        arch=arch, algo=algo,
        fed=FedConfig(num_clients=clients, clients_per_round=cohort,
                      local_steps=local_steps, device_batch_size=batch,
                      server_batch_size=2 * batch, dirichlet_alpha=alpha,
                      seed=seed),
        optim=OptimConfig(name="momentum", lr=lr, schedule="inverse_time",
                          decay_gamma=0.005),
        seed=seed)
    train = make_dataset_for_model(model, n_train, seq_len=seq_len, seed=seed)
    evald = make_dataset_for_model(model, n_eval, seq_len=seq_len,
                                   seed=seed + 1)
    cl = federate(train, clients, alpha, seed=seed)
    return model, run_cfg, cl, evald
