"""Perf trajectory benchmark: flash-attention forward + backward kernels.

Quantifies the fused single-recompute backward (PR 5) against the legacy
two-sweep schedule — the fused kernel recomputes each (q-tile, kv-tile)
probability tile once for all three gradients and reads Q/K/V/dO from HBM
once instead of twice.  Gated in CI against the committed
``BENCH_attention.json`` (``benchmarks/run.py --gate``), same pattern as
``BENCH_step.json`` / ``BENCH_fleet.json``.

  PYTHONPATH=src python -m benchmarks.run --only bench_attention

Output ``BENCH_attention.json`` fields:

* ``config``            — attention shape measured (CPU smoke scale here;
  interpret-mode Pallas lowers to plain XLA so the ratio understates the
  HBM-traffic win on TPU).
* ``times_s``           — best-of-``reps`` wall-clock seconds:
  ``fa_fwd``, ``fa_bwd_fused``, ``fa_bwd_split``.
* ``bwd_speedup_fused`` — fa_bwd_split / fa_bwd_fused.
"""

from __future__ import annotations

import json

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import best as _best, save, table

BENCH_PATH = "BENCH_attention.json"


def run(quick: bool = True):
    from repro.kernels.flash_attention import ops as fa_ops

    reps = 3 if quick else 10
    B, S, Hkv, G, hd = 2, 128, 2, 2, 32
    bq = bk = 32
    causal, window, softcap = True, 0, 0.0
    scale = 1.0 / np.sqrt(hd)
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.normal(0, 1, (B, S, Hkv, G, hd)), jnp.float32)
    k = jnp.asarray(rng.normal(0, 1, (B, S, Hkv, hd)), jnp.float32)
    v = jnp.asarray(rng.normal(0, 1, (B, S, Hkv, hd)), jnp.float32)

    fwd = jax.jit(lambda q, k, v: fa_ops.flash_attention(
        q, k, v, causal, window, softcap, scale, bq, bk))

    def grad_fn(strategy):
        return jax.jit(jax.grad(
            lambda q, k, v: jnp.sum(jnp.sin(fa_ops.flash_attention(
                q, k, v, causal, window, softcap, scale, bq, bk, strategy))),
            argnums=(0, 1, 2)))

    bwd_fused = grad_fn("fused")
    bwd_split = grad_fn("split")
    fwd(q, k, v).block_until_ready()                    # compile
    jax.block_until_ready(bwd_fused(q, k, v))
    jax.block_until_ready(bwd_split(q, k, v))

    times = {
        "fa_fwd": _best(lambda: fwd(q, k, v).block_until_ready(), reps),
        "fa_bwd_fused": _best(
            lambda: jax.block_until_ready(bwd_fused(q, k, v)), reps),
        "fa_bwd_split": _best(
            lambda: jax.block_until_ready(bwd_split(q, k, v)), reps),
    }
    speedup = times["fa_bwd_split"] / times["fa_bwd_fused"]
    payload = {
        "config": {"B": B, "S": S, "Hkv": Hkv, "G": G, "hd": hd,
                   "block_q": bq, "block_k": bk, "causal": causal,
                   "backend": jax.default_backend()},
        "times_s": {k_: round(v_, 6) for k_, v_ in times.items()},
        "bwd_speedup_fused": round(speedup, 3),
    }
    with open(BENCH_PATH, "w") as f:
        json.dump(payload, f, indent=1)
        f.write("\n")
    save("bench_attention", payload)

    rows = [{"metric": k_, "seconds": v_} for k_, v_ in times.items()]
    rows.append({"metric": "bwd speedup (split/fused)", "seconds": speedup})
    table(rows, ["metric", "seconds"],
          "bench_attention — flash-attention wall clock")
    return payload


if __name__ == "__main__":
    run(quick=False)
