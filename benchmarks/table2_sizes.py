"""Paper Table 2: model / auxiliary / activation sizes at split point p=1
for the paper's four architectures (ours, exact, fp32 like the paper)."""

from __future__ import annotations

from benchmarks.common import gb, save, table
from repro.configs import registry
from repro.configs.base import SplitConfig
from repro.core import comm_model
from repro.models import build_model

N_SAMPLES = 50_000


def run(quick: bool = True):
    rows = []
    for arch in ("mobilenet-l", "vgg11", "swin-t", "vit-s"):
        model = build_model(registry.get_config(arch))
        sizes = comm_model.split_sizes(model, SplitConfig(split_point=1))
        rows.append({
            "model": arch,
            "s_act_GB": gb(sizes.act_per_sample * N_SAMPLES),
            "s_d_GB": gb(sizes.device),
            "s_aux_GB": gb(sizes.aux),
            "s_s_GB": gb(sizes.server),
        })
        # the paper's structural relations: s_act >> s_s >> s_aux ~ s_d
        assert rows[-1]["s_act_GB"] > rows[-1]["s_s_GB"]
        assert rows[-1]["s_s_GB"] > rows[-1]["s_aux_GB"]
    table(rows, ["model", "s_act_GB", "s_d_GB", "s_aux_GB", "s_s_GB"],
          "Table 2 — sizes at p=1 (50k samples, fp32)")
    save("table2_sizes", rows)
    return rows


if __name__ == "__main__":
    run()
