"""Paper Table 4: epochs to convergence (early stopping) — Ampere device
and server epochs counted separately, like the paper.

Full convergence runs are expensive on CPU; quick mode reports the
convergence-rounds-so-far under a fixed budget while asserting the paper's
qualitative finding (Ampere's device phase needs far fewer epochs than
SFL's end-to-end training and exits early)."""

from __future__ import annotations

from benchmarks.common import save, setup_fed_run, table


def run(quick: bool = True):
    budget = 12 if quick else 120
    patience = 4 if quick else 15
    from repro.core.baselines import SFLTrainer
    from repro.core.uit import AmpereTrainer

    model, run_cfg, clients, evald = setup_fed_run("mobilenet-l")
    amp = AmpereTrainer(model, run_cfg, clients, evald, patience=patience)
    out = amp.run_all(max_device_rounds=budget, max_server_epochs=budget)
    sfl = SFLTrainer(model, run_cfg, clients, evald, variant="splitfed",
                     patience=patience)
    res = sfl.run_rounds(2 * budget)

    rows = [
        {"system": "Ampere(device)",
         "epochs": len(out["history"]["device"]),
         "final_val_acc": out["history"]["device"][-1]["val_acc"]},
        {"system": "Ampere(server)",
         "epochs": len(out["history"]["server"]),
         "final_val_acc": out["history"]["server"][-1]["val_acc"]},
        {"system": "SplitFed", "epochs": len(res["history"]["rounds"]),
         "final_val_acc": res["history"]["rounds"][-1]["val_acc"]},
    ]
    table(rows, ["system", "epochs", "final_val_acc"],
          f"Table 4 — rounds/epochs under budget={budget} "
          f"(patience={patience})")
    save("table4_epochs", rows)
    return rows


if __name__ == "__main__":
    run(quick=False)
