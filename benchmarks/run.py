"""Benchmark entry point: one module per paper table/figure.

  PYTHONPATH=src python -m benchmarks.run            # quick pass (~15 min)
  PYTHONPATH=src python -m benchmarks.run --full     # full training curves
  PYTHONPATH=src python -m benchmarks.run --only table1_comm_rounds,fig10

Analytic benchmarks (Tables 1/2/5, Figs 3/6/7/9) are exact at the paper's
full scale; training benchmarks (Figs 8/10/11, Table 4) run the real
federated systems at smoke scale on synthetic non-IID data.  The roofline
benchmark reads the dry-run matrix results when present.

``bench_step`` / ``bench_fleet`` / ``bench_attention`` are the
perf-trajectory gates (not paper figures): they time the step paths /
fleet paths / flash-attention kernels and write ``BENCH_step.json`` /
``BENCH_fleet.json`` / ``BENCH_attention.json`` at the repo root —
``{"config": {...}, "times_s": {name: best-of-N seconds}, ...}``.
Run one alone with ``--only bench_step``; compare two snapshots with
``python scripts/check_bench_regression.py old.json new.json`` (exits
nonzero on step-time regression).

``--gate`` is the CI mode (``scripts/ci.sh``): it snapshots the committed
``BENCH_*.json``, re-runs just the gate benchmarks, and fails if any
``times_s`` entry regressed beyond ``--gate-threshold`` (default 25% —
CPU CI boxes are noisy; the trend lives in the committed snapshots).
"""

from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import tempfile
import time
import traceback

from benchmarks import (
    bench_attention,
    bench_cut,
    bench_fleet,
    bench_step,
    fig3_fig6_splitpoint,
    fig7_aux_ratio,
    fig8_accuracy_time,
    fig9_device_compute,
    fig10_noniid,
    fig11_consolidation,
    roofline,
    table1_comm_rounds,
    table2_sizes,
    table4_epochs,
    table5_comm_volume,
)

BENCHMARKS = {
    "table1_comm_rounds": table1_comm_rounds.run,
    "table2_sizes": table2_sizes.run,
    "fig3_fig6_splitpoint": fig3_fig6_splitpoint.run,
    "fig7_aux_ratio": fig7_aux_ratio.run,
    "table5_comm_volume": table5_comm_volume.run,
    "fig9_device_compute": fig9_device_compute.run,
    "fig8_accuracy_time": fig8_accuracy_time.run,
    "fig10_noniid": fig10_noniid.run,
    "fig11_consolidation": fig11_consolidation.run,
    "table4_epochs": table4_epochs.run,
    "roofline": roofline.run,
    "bench_step": bench_step.run,
    "bench_fleet": bench_fleet.run,
    "bench_attention": bench_attention.run,
    "bench_cut": bench_cut.run,
}

# gate benchmarks: name -> committed snapshot they rewrite
GATED = {"bench_step": bench_step.BENCH_PATH,
         "bench_fleet": bench_fleet.BENCH_PATH,
         "bench_attention": bench_attention.BENCH_PATH,
         "bench_cut": bench_cut.BENCH_PATH}


def run_gate(threshold: float) -> int:
    """Re-run the gate benchmarks and compare against the committed
    BENCH files.  The committed snapshot is restored afterwards — gating
    never moves the baseline (updating it is an explicit
    ``--only bench_step`` / ``--only bench_fleet`` run that gets
    committed), so a failed gate keeps failing on retry."""
    from benchmarks import common

    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    os.chdir(root)   # bench modules write repo-root-relative BENCH paths
    check = os.path.join(root, "scripts", "check_bench_regression.py")
    rc = 0
    for name, path in GATED.items():
        if not os.path.exists(path):
            print(f"[gate] {name}: no committed {path}; writing fresh "
                  f"baseline")
            BENCHMARKS[name](quick=True)
            continue
        # the bench rewrites its committed snapshot AND its results/ copy;
        # snapshot both so gating leaves the workspace exactly as it was
        touched = {path: None,
                   os.path.join(common.RESULTS_DIR, f"{name}.json"): None}
        for p in touched:
            if os.path.exists(p):
                with open(p) as f:
                    touched[p] = f.read()
        with tempfile.NamedTemporaryFile("w", suffix=".json",
                                         delete=False) as tf:
            tf.write(touched[path])
            baseline = tf.name
        try:
            print(f"\n===== gate: {name} =====", flush=True)
            BENCHMARKS[name](quick=True)
            res = subprocess.run(
                [sys.executable, check, baseline, path,
                 "--threshold", str(threshold)])
            if res.returncode != 0:
                rc = 1
        finally:
            for p, content in touched.items():  # gate never moves baselines
                if content is not None:
                    with open(p, "w") as f:
                        f.write(content)
                elif os.path.exists(p):
                    os.unlink(p)
            os.unlink(baseline)
    return rc


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--only", default="")
    ap.add_argument("--gate", action="store_true",
                    help="run only the gate benchmarks and fail on "
                         "regression vs the committed BENCH_*.json")
    ap.add_argument("--gate-threshold", type=float, default=0.25)
    ap.add_argument("--profile", action="store_true",
                    help="wrap the benchmark run in jax.profiler.trace "
                         "(dump under results/profile) and activate "
                         "kernel-site trace annotations")
    args = ap.parse_args(argv)
    if args.gate:
        sys.exit(run_gate(args.gate_threshold))
    only = [s for s in args.only.split(",") if s]

    if args.profile:
        from repro.observability.profiling import profile_run
        profile_cm = profile_run(os.path.join("results", "profile"))
    else:
        import contextlib
        profile_cm = contextlib.nullcontext()

    failures = []
    with profile_cm:
        for name, fn in BENCHMARKS.items():
            if only and not any(o in name for o in only):
                continue
            t0 = time.time()
            print(f"\n===== {name} =====", flush=True)
            try:
                fn(quick=not args.full)
                print(f"[{name}] ok in {time.time()-t0:.1f}s", flush=True)
            except Exception:
                traceback.print_exc()
                failures.append(name)
                print(f"[{name}] FAILED", flush=True)
    print(f"\n{len(BENCHMARKS) - len(failures)}/{len(BENCHMARKS)} "
          f"benchmarks ok" + (f"; failed: {failures}" if failures else ""))
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
