"""Benchmark entry point: one module per paper table/figure.

  PYTHONPATH=src python -m benchmarks.run            # quick pass (~15 min)
  PYTHONPATH=src python -m benchmarks.run --full     # full training curves
  PYTHONPATH=src python -m benchmarks.run --only table1_comm_rounds,fig10

Analytic benchmarks (Tables 1/2/5, Figs 3/6/7/9) are exact at the paper's
full scale; training benchmarks (Figs 8/10/11, Table 4) run the real
federated systems at smoke scale on synthetic non-IID data.  The roofline
benchmark reads the dry-run matrix results when present.

``bench_step`` is the perf-trajectory gate (not a paper figure): it times
the xent kernel fwd/bwd, one server step, one seed-style host-loop server
epoch vs the jitted device-resident epoch, and one device round, then
writes ``BENCH_step.json`` at the repo root —
``{"config": {...}, "times_s": {name: best-of-N seconds}, "speedup_epoch"}``.
Run it alone with ``--only bench_step``; compare two snapshots with
``python scripts/check_bench_regression.py old.json new.json`` (exits
nonzero on >10% step-time regression).
"""

from __future__ import annotations

import argparse
import sys
import time
import traceback

from benchmarks import (
    bench_step,
    fig3_fig6_splitpoint,
    fig7_aux_ratio,
    fig8_accuracy_time,
    fig9_device_compute,
    fig10_noniid,
    fig11_consolidation,
    roofline,
    table1_comm_rounds,
    table2_sizes,
    table4_epochs,
    table5_comm_volume,
)

BENCHMARKS = {
    "table1_comm_rounds": table1_comm_rounds.run,
    "table2_sizes": table2_sizes.run,
    "fig3_fig6_splitpoint": fig3_fig6_splitpoint.run,
    "fig7_aux_ratio": fig7_aux_ratio.run,
    "table5_comm_volume": table5_comm_volume.run,
    "fig9_device_compute": fig9_device_compute.run,
    "fig8_accuracy_time": fig8_accuracy_time.run,
    "fig10_noniid": fig10_noniid.run,
    "fig11_consolidation": fig11_consolidation.run,
    "table4_epochs": table4_epochs.run,
    "roofline": roofline.run,
    "bench_step": bench_step.run,
}


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--only", default="")
    args = ap.parse_args(argv)
    only = [s for s in args.only.split(",") if s]

    failures = []
    for name, fn in BENCHMARKS.items():
        if only and not any(o in name for o in only):
            continue
        t0 = time.time()
        print(f"\n===== {name} =====", flush=True)
        try:
            fn(quick=not args.full)
            print(f"[{name}] ok in {time.time()-t0:.1f}s", flush=True)
        except Exception:
            traceback.print_exc()
            failures.append(name)
            print(f"[{name}] FAILED", flush=True)
    print(f"\n{len(BENCHMARKS) - len(failures)}/{len(BENCHMARKS)} "
          f"benchmarks ok" + (f"; failed: {failures}" if failures else ""))
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
