"""Adaptive-cut machinery perf gate: frontier sweep, policy resolution,
prefix aggregation.

Writes ``BENCH_cut.json`` at the repo root (same contract as
``BENCH_step.json``: ``times_s`` entries are gated by
``scripts/check_bench_regression.py``).

Measured:

* ``cut_frontier_mobilenet_l`` / ``cut_frontier_vit_s`` — one full
  per-class cut-frontier sweep (every device class x every legal depth)
  at the paper-scale configs.  Analytic only — this is the cost-model
  hot path ``resolve_cuts`` runs once per experiment, and it must stay
  cheap enough to call at spec-resolution time.
* ``resolve_cuts_120dev`` — full ``CutPolicy`` resolution: the per-class
  frontier plus the deterministic class->device mapping over a sampled
  120-device population.
* ``prefix_fedavg_2depth`` — heterogeneous consolidation micro-gate:
  folding two trained depth buckets back over the shared prefix of a
  device stack (the per-round aggregation step of a two-depth fleet).

The payload also records the cut each class picks at full scale
(``cuts_mobilenet_l`` / ``cuts_vit_s``) so cost-model drift shows up in
review, not just runtime drift.

  PYTHONPATH=src python -m benchmarks.run --only bench_cut
"""

from __future__ import annotations

import dataclasses
import json

import jax

from benchmarks.common import best as _best, save, table

BENCH_PATH = "BENCH_cut.json"


def _bench_frontier(reps: int):
    from repro.configs import registry
    from repro.experiments.spec import ExperimentSpec
    from repro.fleet import profiles
    from repro.fleet.cuts import CutPolicy, class_frontier
    from repro.models import build_model

    pol = CutPolicy(mode="per_profile")
    run = ExperimentSpec().run
    times, extras = {}, {}
    for arch in ("mobilenet-l", "vit-s"):
        model = build_model(registry.get_config(arch))
        split = dataclasses.replace(run.split, split_point=1)
        key = arch.replace("-", "_")

        def sweep(model=model, split=split):
            out = {}
            sizes_by_cut = {}   # shared across classes, as resolve_cuts does
            for name, cls in profiles.DEVICE_CLASSES.items():
                rows = class_frontier(
                    model, split, cls, policy=pol, n_samples=256,
                    batch_size=32, device_epochs=55, upload_samples=512,
                    sizes_by_cut=sizes_by_cut)
                out[name] = min(rows, key=lambda r: (r["total_s"],
                                                     r["split_point"])
                                )["split_point"]
            return out

        extras[f"cuts_{key}"] = sweep()
        times[f"cut_frontier_{key}"] = _best(sweep, reps)
    return times, extras


def _bench_resolve(reps: int):
    from repro.configs import registry
    from repro.experiments.spec import ExperimentSpec
    from repro.fleet.cuts import CutPolicy, resolve_cuts
    from repro.fleet.profiles import FleetConfig
    from repro.models import build_model

    run = ExperimentSpec().run
    model = build_model(registry.get_config("mobilenet-l"))
    fleet = FleetConfig(n_devices=120)
    pol = CutPolicy(mode="per_profile")

    def resolve():
        return resolve_cuts(pol, model, run, fleet)

    a = resolve()
    return ({"resolve_cuts_120dev": _best(resolve, reps)},
            {"resolved_uniform": a.uniform,
             "resolved_depths": list(a.depths)})


def _bench_prefix(reps: int):
    from repro.configs import registry
    from repro.core import aggregation, splitting
    from repro.models import build_model

    model = build_model(registry.get_smoke_config("mobilenet-l"))
    params = model.init(jax.random.PRNGKey(0))
    p_max = model.cfg.num_layers - 1
    dev, _ = splitting.split_params(model, params, p_max)
    shallow = {"layers": [jax.tree.map(lambda a: a * 1.01, layer)
                          for layer in dev["layers"][:1]]}
    deep = {"layers": [jax.tree.map(lambda a: a * 0.99, layer)
                       for layer in dev["layers"][:p_max - 1]]}
    by_depth = {1: shallow, p_max - 1: deep}
    w = {1: 0.5, p_max - 1: 0.5}

    def agg():
        out = aggregation.prefix_fedavg(dev, by_depth, w)
        jax.block_until_ready(out)
        return out

    agg()   # compile/warm
    return {"prefix_fedavg_2depth": _best(agg, reps)}, {}


def run(quick: bool = True):
    reps = 3 if quick else 10
    times, config = {}, {}
    for bench in (_bench_frontier, _bench_resolve, _bench_prefix):
        t, c = bench(reps)
        times.update(t)
        config.update(c)

    payload = {"config": config,
               "times_s": {k: round(v, 6) for k, v in times.items()}}
    with open(BENCH_PATH, "w") as f:
        json.dump(payload, f, indent=1)
        f.write("\n")
    save("bench_cut", payload)

    rows = [{"metric": k, "value": v} for k, v in times.items()]
    rows += [{"metric": f"full-scale cuts ({k.split('_', 1)[1]})",
              "value": json.dumps(v)}
             for k, v in config.items() if k.startswith("cuts_")]
    table(rows, ["metric", "value"],
          "bench_cut — adaptive-cut machinery wall clock")
    return payload


if __name__ == "__main__":
    run(quick=False)
