"""§Roofline table: read the dry-run result rows (results/dryrun/merged.json
by default) and print the per-(arch x shape) roofline terms for the
single-pod mesh — compute / memory / collective seconds, the dominant
term, MODEL_FLOPS/HLO_FLOPs, and the roofline fraction."""

from __future__ import annotations

import json
import os

from benchmarks.common import save, table

DEFAULT = os.environ.get("REPRO_DRYRUN", "results/dryrun/merged.json")


def run(quick: bool = True, path: str = DEFAULT):
    if not os.path.exists(path):
        print(f"[roofline] no dry-run results at {path}; run "
              "scripts/run_dryrun_matrix.sh first")
        return []
    with open(path) as f:
        rows = json.load(f)
    out = []
    for r in rows:
        if r.get("status") == "skip":
            out.append({"arch": r["arch"], "shape": r["shape"],
                        "bottleneck": "SKIP(full-attn @500k)"})
            continue
        if r.get("status") != "ok" or r.get("mesh") != "single_pod":
            continue
        out.append({
            "arch": r["arch"], "shape": r["shape"], "step": r["step"],
            "t_compute_ms": r["t_compute_ms"],
            "t_memory_ms": r["t_memory_ms"],
            "t_collective_ms": r["t_collective_ms"],
            "bottleneck": r["bottleneck"],
            "useful_frac": r.get("useful_flops_frac", 0),
            "roofline_frac": r.get("roofline_frac", 0),
            "peak_GB": r.get("peak_mem_gb_per_device", 0),
        })
    table(out, ["arch", "shape", "step", "t_compute_ms", "t_memory_ms",
                "t_collective_ms", "bottleneck", "useful_frac",
                "roofline_frac", "peak_GB"],
          "§Roofline — single-pod (256 chips), per (arch x shape)")
    save("roofline_table", out)
    return out


if __name__ == "__main__":
    run()
