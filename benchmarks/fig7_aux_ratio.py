"""Paper Fig. 7: auxiliary-network dimension ratio vs on-device compute
(exact, analytic) and final model accuracy (smoke-scale training)."""

from __future__ import annotations

from benchmarks.common import save, setup_fed_run, table
from repro.configs import registry
from repro.configs.base import SplitConfig, replace
from repro.core import comm_model
from repro.models import build_model

RATIOS = (0.25, 0.5, 0.75, 1.0)


def run(quick: bool = True):
    # compute curve on the FULL MobileNet-L (like the paper's x-axis)
    model = build_model(registry.get_config("mobilenet-l"))
    rows = []
    for r in RATIOS:
        sc = SplitConfig(split_point=1, aux_ratio=r)
        fl = comm_model.device_flops_per_sample(model, sc, "ampere")
        sizes = comm_model.split_sizes(model, sc)
        rows.append({"ratio": r, "device_GFLOPs_per_sample": fl / 1e9,
                     "aux_MB": sizes.aux / 1e6})
    flops = [r["device_GFLOPs_per_sample"] for r in rows]
    assert flops == sorted(flops)  # compute grows with the ratio

    if not quick:
        from repro.core.uit import AmpereTrainer
        for row, r in zip(rows, RATIOS):
            m, run_cfg, clients, evald = setup_fed_run("mobilenet-l")
            run_cfg = replace(run_cfg, split=SplitConfig(split_point=1,
                                                         aux_ratio=r))
            tr = AmpereTrainer(m, run_cfg, clients, evald, patience=100)
            out = tr.run_all(max_device_rounds=20, max_server_epochs=10)
            row["final_acc"] = out["history"]["server"][-1]["val_acc"]
    table(rows, ["ratio", "device_GFLOPs_per_sample", "aux_MB"]
          + (["final_acc"] if not quick else []),
          "Fig 7 — auxiliary dimension ratio")
    save("fig7_aux_ratio", rows)
    return rows


if __name__ == "__main__":
    run(quick=False)
