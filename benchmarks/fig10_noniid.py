"""Paper Fig. 10: accuracy across non-IID degrees alpha in {1.0, 0.33, 0.1}
(Ampere vs SplitFed), plus the accuracy standard deviation across alphas
(the robustness headline)."""

from __future__ import annotations

import numpy as np

from benchmarks.common import save, setup_fed_run, table


def run(quick: bool = True):
    rounds = 8 if quick else 50
    server_epochs = 5 if quick else 25
    alphas = [1.0, 0.33, 0.1]
    from repro.core.baselines import SFLTrainer
    from repro.core.uit import AmpereTrainer

    rows = []
    accs = {"ampere": [], "splitfed": []}
    for alpha in alphas:
        model, run_cfg, clients, evald = setup_fed_run("mobilenet-l",
                                                       alpha=alpha)
        amp = AmpereTrainer(model, run_cfg, clients, evald, patience=100)
        out = amp.run_all(max_device_rounds=rounds,
                          max_server_epochs=server_epochs)
        a_acc = out["history"]["server"][-1]["val_acc"]
        sfl = SFLTrainer(model, run_cfg, clients, evald, variant="splitfed",
                         patience=100)
        res = sfl.run_rounds(rounds)
        s_acc = res["history"]["rounds"][-1]["val_acc"]
        accs["ampere"].append(a_acc)
        accs["splitfed"].append(s_acc)
        rows.append({"alpha": alpha, "ampere_acc": a_acc,
                     "splitfed_acc": s_acc})
    for name in accs:
        rows.append({"alpha": f"std({name})",
                     "ampere_acc": float(np.std(accs["ampere"]))
                     if name == "ampere" else "",
                     "splitfed_acc": float(np.std(accs["splitfed"]))
                     if name == "splitfed" else ""})
    table(rows, ["alpha", "ampere_acc", "splitfed_acc"],
          f"Fig 10 — accuracy vs non-IID degree ({rounds} rounds)")
    save("fig10_noniid", {"rows": rows, "accs": accs})
    return rows


if __name__ == "__main__":
    run(quick=False)
