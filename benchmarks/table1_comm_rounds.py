"""Paper Table 1: communication volume + rounds for MobileNet-L on
CIFAR-10-scale data — FL vs SFL vs Ampere (exact analytic accounting,
Eqs. 5/27-31; full-size configs, nothing allocated)."""

from __future__ import annotations

from benchmarks.common import gb, save, table
from repro.configs import registry
from repro.configs.base import SplitConfig
from repro.core import comm_model
from repro.models import build_model

EPOCHS = 150            # paper: "both methods train for 150 epochs"
N_SAMPLES = 50_000      # CIFAR-10 train set
BATCH = 32


def run(quick: bool = True):
    model = build_model(registry.get_config("mobilenet-l"))
    sc = SplitConfig(split_point=1)
    sizes = comm_model.split_sizes(model, sc)
    iters = N_SAMPLES // BATCH
    tm = comm_model.TimeModel()

    rows = []
    for algo in ("fedavg", "splitfed", "ampere"):
        vol = comm_model.comm_volume(algo, sizes, epochs=EPOCHS,
                                     n_samples=N_SAMPLES,
                                     device_epochs=EPOCHS)
        rounds = comm_model.comm_rounds(algo, epochs=EPOCHS,
                                        iters_per_epoch=iters,
                                        device_epochs=EPOCHS)
        t_epoch = comm_model.epoch_time(algo, model, sc, tm,
                                        n_samples=N_SAMPLES,
                                        batch_size=BATCH, sizes=sizes)
        rows.append({
            "system": {"fedavg": "FL", "splitfed": "SFL",
                       "ampere": "Ampere"}[algo],
            "comm_volume_GB": gb(vol),
            "comm_rounds_total": rounds,
            "rounds_per_hour": rounds / max(1e-9, EPOCHS * t_epoch / 3600),
        })
    table(rows, ["system", "comm_volume_GB", "comm_rounds_total",
                 "rounds_per_hour"],
          "Table 1 — comm volume & frequency (MobileNet-L, 150 epochs)")
    save("table1_comm_rounds", rows)
    # paper's qualitative orderings must hold
    fl, sfl, amp = rows
    assert sfl["comm_volume_GB"] > fl["comm_volume_GB"]
    assert sfl["comm_rounds_total"] > 1000 * fl["comm_rounds_total"]
    assert amp["comm_volume_GB"] < fl["comm_volume_GB"]
    return rows


if __name__ == "__main__":
    run()
