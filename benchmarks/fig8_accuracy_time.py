"""Paper Fig. 8: accuracy vs (simulated) training time — Ampere against
the SFL baselines, real training at smoke scale on synthetic non-IID data,
wall-time from the testbed time model."""

from __future__ import annotations

from benchmarks.common import save, setup_fed_run, table


def run(quick: bool = True):
    rounds = 10 if quick else 60
    server_epochs = 6 if quick else 30
    variants = ["splitfed"] if quick else ["splitfed", "pipar", "splitgp",
                                           "scaffold"]
    model, run_cfg, clients, evald = setup_fed_run("mobilenet-l")

    from repro.core.baselines import SFLTrainer
    from repro.core.uit import AmpereTrainer

    results = {}
    amp = AmpereTrainer(model, run_cfg, clients, evald, patience=100)
    out = amp.run_all(max_device_rounds=rounds, max_server_epochs=server_epochs)
    results["ampere"] = {
        "final_acc": out["history"]["server"][-1]["val_acc"],
        "sim_time_s": out["history"]["sim_time"],
        "comm_GB": out["history"]["comm_bytes"] / 1e9,
        "curve": [r["val_acc"] for r in out["history"]["server"]],
    }
    for v in variants:
        tr = SFLTrainer(model, run_cfg, clients, evald, variant=v,
                        patience=100)
        res = tr.run_rounds(rounds)
        results[v] = {
            "final_acc": res["history"]["rounds"][-1]["val_acc"],
            "sim_time_s": res["history"]["sim_time"],
            "comm_GB": res["history"]["comm_bytes"] / 1e9,
            "curve": [r["val_acc"] for r in res["history"]["rounds"]],
        }
    rows = [{"system": k, **{kk: vv for kk, vv in v.items() if kk != "curve"}}
            for k, v in results.items()]
    table(rows, ["system", "final_acc", "sim_time_s", "comm_GB"],
          f"Fig 8 — accuracy vs time ({rounds} rounds, smoke scale)")
    save("fig8_accuracy_time", results)
    return results


if __name__ == "__main__":
    run(quick=False)
