"""Paper Fig. 9: total on-device computation (TFLOPs) to convergence."""

from __future__ import annotations

from benchmarks.common import save, table
from repro.benchmarks_epochs import EPOCHS_TABLE4
from repro.configs import registry
from repro.configs.base import SplitConfig
from repro.core import comm_model
from repro.models import build_model

N_SAMPLES = 10_000


def run(quick: bool = True):
    rows = []
    for arch in ("mobilenet-l", "vgg11", "swin-t", "vit-s"):
        model = build_model(registry.get_config(arch))
        sc = SplitConfig(split_point=1)
        row = {"model": arch}
        for algo in ("splitfed", "pipar", "scaffold", "splitgp", "ampere"):
            ep = EPOCHS_TABLE4[arch][algo]
            ep_dev = ep[0] if isinstance(ep, tuple) else ep
            fl = comm_model.device_flops_per_sample(model, sc, algo)
            row[algo + "_TFLOPs"] = fl * N_SAMPLES * ep_dev / 1e12
        rows.append(row)
        # paper: Ampere uses 6.87%-96.2% of the baselines' device compute —
        # strictly below the aux-carrying baseline (SplitGP, same per-sample
        # cost but 3-5x the epochs); vs lean SplitFed the ratio depends on
        # s_aux/s_d and can approach parity (the paper's 96.2% case).
        assert row["ampere_TFLOPs"] < row["splitgp_TFLOPs"]
        row["pct_of_splitgp"] = (100 * row["ampere_TFLOPs"]
                                 / row["splitgp_TFLOPs"])
    table(rows, ["model"] + [a + "_TFLOPs" for a in
                             ("splitfed", "pipar", "scaffold", "splitgp",
                              "ampere")],
          "Fig 9 — on-device computation to convergence (TFLOPs)")
    save("fig9_device_compute", rows)
    return rows


if __name__ == "__main__":
    run()
