"""Paper Fig. 11 (ablation): Ampere with vs without activation
consolidation.  Without consolidation the server trains K per-client
blocks on per-client activation pools and aggregates them each epoch (the
SFL-style arm the paper compares against)."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import save, setup_fed_run, table
from repro.core import aggregation, evaluate, splitting, steps
from repro.data import ActivationStore
from repro.models import build_model


def _train_server_no_consolidation(model, run_cfg, dev_state, srv_params,
                                   store, evald, epochs):
    """K per-client server blocks on per-client pools, FedAvg'd per epoch."""
    step_fn = jax.jit(steps.make_server_train_step(model, run_cfg))
    clients = store.clients()
    merged_model = build_model(splitting.merged_config(model))
    eval_step = evaluate.make_eval_step(merged_model)
    global_srv = srv_params
    curve = []
    for _ in range(epochs):
        per_client, weights = [], []
        for cid in clients:
            st = steps.init_server_state(model, run_cfg, global_srv)
            for batch in store.batches(run_cfg.fed.server_batch_size,
                                       epochs=1, client_id=cid):
                batch = {k: jnp.asarray(v) for k, v in batch.items()}
                st, _ = step_fn(st, batch)
            per_client.append(st["server"])
            weights.append(store.num_samples(cid))
        global_srv = aggregation.fedavg(per_client, weights)
        merged = splitting.merge_params(model, dev_state["device"],
                                        global_srv,
                                        run_cfg.split.split_point)
        curve.append(evaluate.evaluate(merged_model, merged, evald,
                                       eval_step=eval_step)["acc"])
    return curve


def run(quick: bool = True):
    rounds = 8 if quick else 50
    epochs = 5 if quick else 25
    from repro.core.uit import AmpereTrainer
    model, run_cfg, clients, evald = setup_fed_run("mobilenet-l")

    # shared device phase
    tr = AmpereTrainer(model, run_cfg, clients, evald, patience=100)
    key = jax.random.PRNGKey(0)
    dev, srv, aux = tr._init_states(key)
    dev_state = tr.run_device_phase({"device": dev, "aux": aux},
                                    max_rounds=rounds)

    # with consolidation
    store_c = ActivationStore(consolidated=True, seed=0)
    tr.generate_activations(dev_state, store_c)
    srv_state = tr.run_server_phase(dev_state, srv, store_c,
                                    max_epochs=epochs)
    acc_with = tr.history["server"][-1]["val_acc"]

    # without consolidation (per-client pools + K server blocks)
    store_n = ActivationStore(consolidated=False, seed=0)
    tr2 = AmpereTrainer(model, run_cfg, clients, evald, patience=100,
                        consolidate=False)
    tr2.generate_activations(dev_state, store_n)
    curve = _train_server_no_consolidation(model, run_cfg, dev_state, srv,
                                           store_n, evald, epochs)
    acc_without = curve[-1]

    rows = [{"variant": "Ampere w/ consolidation", "final_acc": acc_with},
            {"variant": "Ampere w/o consolidation", "final_acc": acc_without}]
    table(rows, ["variant", "final_acc"],
          f"Fig 11 — activation consolidation ablation ({epochs} epochs)")
    save("fig11_consolidation", rows)
    return rows


if __name__ == "__main__":
    run(quick=False)
